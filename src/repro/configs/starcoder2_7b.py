"""starcoder2-7b — dense GQA, RoPE [arXiv:2402.19173]."""
from repro.config.base import ArchFamily, ModelConfig
from repro.config.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b",
        family=ArchFamily.DENSE,
        num_layers=32,
        d_model=4608,
        num_heads=36,
        num_kv_heads=4,
        d_ff=18432,
        vocab_size=49152,
        source="arXiv:2402.19173",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b-reduced",
        family=ArchFamily.DENSE,
        num_layers=2,
        d_model=144,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        source="reduced",
    )


register("starcoder2-7b", full, reduced)
