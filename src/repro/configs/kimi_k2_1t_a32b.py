"""kimi-k2-1t-a32b — trillion-param MoE, 384 routed experts top-8 [arXiv:2501.kimi2].

Routed experts: 61L x (3 * 7168 * 2048 * 384) ~ 1.03T params; top-8 active
~32B. One shared expert per the K2 card.
"""
from repro.config.base import ArchFamily, ModelConfig, MoEConfig
from repro.config.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b",
        family=ArchFamily.MOE,
        num_layers=61,
        d_model=7168,
        num_heads=64,
        num_kv_heads=8,
        d_ff=2048,
        vocab_size=163840,
        moe=MoEConfig(
            num_experts=384,
            num_experts_per_tok=8,
            num_shared_experts=1,
            expert_ff_dim=2048,
            shared_ff_dim=2048,
        ),
        source="arXiv:2501.kimi2",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b-reduced",
        family=ArchFamily.MOE,
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=64,
        vocab_size=512,
        moe=MoEConfig(
            num_experts=4,
            num_experts_per_tok=2,
            num_shared_experts=1,
            expert_ff_dim=64,
            shared_ff_dim=64,
        ),
        source="reduced",
    )


register("kimi-k2-1t-a32b", full, reduced)
