"""qwen2-moe-a2.7b — 4 shared + 60 routed top-4 MoE [hf:Qwen/Qwen1.5-MoE-A2.7B]."""
from repro.config.base import ArchFamily, ModelConfig, MoEConfig
from repro.config.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b",
        family=ArchFamily.MOE,
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=1408,
        vocab_size=151936,
        qkv_bias=True,
        moe=MoEConfig(
            num_experts=60,
            num_experts_per_tok=4,
            num_shared_experts=4,
            expert_ff_dim=1408,
            shared_ff_dim=5632,   # 4 shared experts fused: 4 * 1408
        ),
        source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b-reduced",
        family=ArchFamily.MOE,
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=96,
        vocab_size=512,
        qkv_bias=True,
        moe=MoEConfig(
            num_experts=4,
            num_experts_per_tok=2,
            num_shared_experts=1,
            expert_ff_dim=96,
            shared_ff_dim=96,
        ),
        source="reduced",
    )


register("qwen2-moe-a2.7b", full, reduced)
