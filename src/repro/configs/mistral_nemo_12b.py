"""mistral-nemo-12b — dense GQA, 128k ctx [hf:mistralai/Mistral-Nemo-Base-2407].

Model card uses head_dim=128 (not d_model/num_heads=160); we follow the card.
For the long_500k shape the dry-run uses the sliding-window variant (window
4096) per DESIGN §4 — full attention at 524k tokens/request is out of scope.
"""
import dataclasses

from repro.config.base import ArchFamily, AttentionKind, ModelConfig
from repro.config.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b",
        family=ArchFamily.DENSE,
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=131072,
        head_dim=128,
        rope_theta=1_000_000.0,
        source="hf:mistralai/Mistral-Nemo-Base-2407",
    )


def sliding(window: int = 4096) -> ModelConfig:
    return dataclasses.replace(
        full(), name="mistral-nemo-12b-swa",
        attention=AttentionKind.SLIDING, sliding_window=window)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b-reduced",
        family=ArchFamily.DENSE,
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        head_dim=32,
        source="reduced",
    )


register("mistral-nemo-12b", full, reduced)
