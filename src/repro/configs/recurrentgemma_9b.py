"""recurrentgemma-9b — RG-LRU + local attention, 2:1 pattern [arXiv:2402.19427]."""
from repro.config.base import (ArchFamily, AttentionKind, ModelConfig,
                               RGLRUConfig)
from repro.config.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family=ArchFamily.HYBRID,
        num_layers=38,
        d_model=4096,
        num_heads=16,
        num_kv_heads=1,            # MQA in the local-attention blocks
        d_ff=12288,
        vocab_size=256000,
        head_dim=256,              # paper: head_dim 256 (16 heads x 256)
        attention=AttentionKind.LOCAL_HYBRID,
        rglru=RGLRUConfig(
            lru_width=4096,
            conv_width=4,
            window_size=2048,
            block_pattern=("recurrent", "recurrent", "attention"),
        ),
        source="arXiv:2402.19427",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b-reduced",
        family=ArchFamily.HYBRID,
        num_layers=3,              # one full recurrent/recurrent/attention pattern
        d_model=128,
        num_heads=4,
        num_kv_heads=1,
        d_ff=256,
        vocab_size=512,
        head_dim=32,
        attention=AttentionKind.LOCAL_HYBRID,
        rglru=RGLRUConfig(
            lru_width=128,
            conv_width=4,
            window_size=64,
            block_pattern=("recurrent", "recurrent", "attention"),
        ),
        source="reduced",
    )


register("recurrentgemma-9b", full, reduced)
