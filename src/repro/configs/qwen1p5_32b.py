"""qwen1.5-32b — dense, QKV bias [hf:Qwen/Qwen1.5-0.5B family scaled]."""
from repro.config.base import ArchFamily, ModelConfig
from repro.config.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b",
        family=ArchFamily.DENSE,
        num_layers=64,
        d_model=5120,
        num_heads=40,
        num_kv_heads=40,
        d_ff=27392,
        vocab_size=152064,
        qkv_bias=True,
        source="hf:Qwen/Qwen1.5-0.5B",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b-reduced",
        family=ArchFamily.DENSE,
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        qkv_bias=True,
        source="reduced",
    )


register("qwen1.5-32b", full, reduced)
