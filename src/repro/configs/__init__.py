"""Assigned-architecture configs. One module per arch; see config.registry."""
