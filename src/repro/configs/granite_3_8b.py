"""granite-3-8b — dense GQA [hf:ibm-granite/granite-3.0-2b-base family]."""
from repro.config.base import ArchFamily, ModelConfig
from repro.config.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b",
        family=ArchFamily.DENSE,
        num_layers=40,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=12800,
        vocab_size=49155,
        tie_embeddings=True,
        source="hf:ibm-granite/granite-3.0-2b-base",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b-reduced",
        family=ArchFamily.DENSE,
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        tie_embeddings=True,
        source="reduced",
    )


register("granite-3-8b", full, reduced)
