"""mamba2-2.7b — attention-free SSD (state-space duality) [arXiv:2405.21060]."""
from repro.config.base import ArchFamily, AttentionKind, ModelConfig, SSMConfig
from repro.config.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b",
        family=ArchFamily.SSM,
        num_layers=64,
        d_model=2560,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        attention=AttentionKind.NONE,
        tie_embeddings=True,
        ssm=SSMConfig(
            state_dim=128,
            head_dim=64,       # 80 SSD heads = expand*d_model/head_dim
            conv_width=4,
            chunk_size=256,
            expand=2,
        ),
        source="arXiv:2405.21060",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b-reduced",
        family=ArchFamily.SSM,
        num_layers=2,
        d_model=128,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=512,
        attention=AttentionKind.NONE,
        tie_embeddings=True,
        ssm=SSMConfig(
            state_dim=16,
            head_dim=32,
            conv_width=4,
            chunk_size=32,
            expand=2,
        ),
        source="reduced",
    )


register("mamba2-2.7b", full, reduced)
