"""seamless-m4t-medium — enc-dec multimodal backbone [arXiv:2308.11596].

The mel/conv audio frontend is a stub per the assignment: input_specs() feeds
precomputed frame embeddings of shape (batch, frames, d_model) to the encoder.
"""
from repro.config.base import ArchFamily, ModelConfig
from repro.config.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium",
        family=ArchFamily.ENCDEC,
        num_layers=12,             # decoder layers
        encoder_layers=12,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=4096,
        vocab_size=256206,
        source="arXiv:2308.11596",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium-reduced",
        family=ArchFamily.ENCDEC,
        num_layers=2,
        encoder_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        source="reduced",
    )


register("seamless-m4t-medium", full, reduced)
