"""llama-3.2-vision-90b — 100L total: 80 self-attn decoder + 20 cross-attn
image layers (one every 4 decoder layers) [hf:meta-llama/Llama-3.2-11B-Vision].

The ViT vision tower + projector is a stub per the assignment: input_specs()
feeds precomputed patch embeddings of shape (batch, num_patches, d_model).
"""
from repro.config.base import ArchFamily, ModelConfig
from repro.config.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b",
        family=ArchFamily.VLM,
        num_layers=80,            # self-attention decoder layers
        num_cross_layers=20,      # + 20 cross-attn layers = 100L total
        vlm_cross_every=4,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=28672,
        vocab_size=128256,
        rope_theta=500000.0,
        source="hf:meta-llama/Llama-3.2-11B-Vision",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b-reduced",
        family=ArchFamily.VLM,
        num_layers=2,
        num_cross_layers=1,
        vlm_cross_every=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        source="reduced",
    )


register("llama-3.2-vision-90b", full, reduced)
