"""Sharding rules: FSDP(data[,pod]) x tensor(model) x expert parallelism.

Logical mapping (DESIGN §5):
  * up-projections  (d -> heads/ffn/experts): in-dim over FSDP axes,
    out-dim over "model"
  * down-projections (heads/ffn -> d): in-dim over "model" (activations
    already model-sharded; XLA inserts the all-reduce), out-dim over FSDP
  * MoE experts: expert axis over "model" (expert parallelism), d over FSDP
  * KV caches: batch over FSDP axes; kv-heads (or head_dim when kv < 16)
    over "model"; batch=1 long-context decode sequence-shards the cache
  * small/1-D tensors replicated

Rules are name-based over the param pytree paths, so every architecture
family resolves through one table.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config.base import ArchFamily, InputShape, ModelConfig


import os


def fsdp_axes(mesh: Mesh):
    """Axes that shard parameters/optimizer state.

    REPRO_POD_MODE=dp keeps FSDP within a pod and makes the pod axis pure
    data parallelism (params replicated per pod, gradient all-reduce across
    pods) — §Perf iteration I: cheaper steady-state collectives when params
    fit per pod, at 2x parameter memory.
    """
    names = mesh.axis_names
    if "pod" in names and os.environ.get("REPRO_POD_MODE", "fsdp") != "dp":
        return ("pod", "data")
    return ("data",)


def data_axes(mesh: Mesh):
    """Axes that shard the batch — always include the pod axis."""
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def _spec_for(name: str, ndim: int, cfg: ModelConfig, fsdp) -> P:
    """PartitionSpec for one (stacked) parameter leaf."""
    f = fsdp if len(fsdp) > 1 else fsdp[0]

    def stacked(*dims):  # prepend the layer-stack axis when present
        return P(*( (None,) * (ndim - len(dims)) + dims ))

    # --- embeddings / head ---------------------------------------------
    # vocab over model, d REPLICATED: sharding d (the head's contracting
    # dim) over data made every (B,T,V) logits tensor a partial sum that
    # XLA all-reduced at full size — 250 GiB/step on a 256k vocab
    # (§Perf iteration F)
    if name.endswith("embed"):
        return P("model", None)                   # (V, d)
    if name.endswith("lm_head"):
        return P(None, "model")                   # (d, V)
    # --- MoE ------------------------------------------------------------
    if "/moe/" in name or name.startswith("moe/"):
        if "router" in name:
            return stacked(f, None)               # (L, d, E)
        if "w_down" in name and "shared" not in name:
            return stacked("model", None, f)      # (L, E, f_e, d)
        if ("w_gate" in name or "w_up" in name) and "shared" not in name:
            return stacked("model", f, None)      # (L, E, d, f_e)
        # shared expert = plain mlp rules below
    # --- attention -------------------------------------------------------
    if name.endswith("attn/wq") or name.endswith("attn/wk") \
            or name.endswith("attn/wv"):
        return stacked(f, "model")                # (L, d, out)
    if name.endswith("attn/wo"):
        return stacked("model", f)                # (L, H*hd, d)
    if name.endswith("attn/bq") or name.endswith("attn/bk") \
            or name.endswith("attn/bv"):
        return stacked("model")
    # --- mlp --------------------------------------------------------------
    if name.endswith("w_gate") or name.endswith("w_up"):
        return stacked(f, "model")
    if name.endswith("w_down"):
        return stacked("model", f)
    # --- mamba2 -------------------------------------------------------------
    if name.endswith("mixer/in_proj"):
        return stacked(f, "model")                # (L, d, d_proj)
    if name.endswith("mixer/out_proj"):
        return stacked("model", f)                # (L, d_in, d)
    if name.endswith("mixer/conv_w"):
        return stacked(None, "model")             # (L, W, ch)
    if name.endswith("mixer/conv_b") or name.endswith("mixer/norm_w"):
        return stacked("model")
    if name.endswith("dt_bias") or name.endswith("A_log") \
            or name.endswith("mixer/D"):
        return stacked(None)                      # (L, H): H=80 not 16-divisible
    # --- RG-LRU ----------------------------------------------------------------
    if name.endswith("rec/w_x") or name.endswith("rec/w_gate_branch"):
        return stacked(f, "model")                # (L, d, w)
    if name.endswith("rec/w_out"):
        return stacked("model", f)                # (L, w, d)
    if name.endswith("rec/w_a") or name.endswith("rec/w_i"):
        return stacked(None, "model")             # (L, w, w)
    if name.endswith("rec/conv_w"):
        return stacked(None, "model")
    if name.endswith("rec/conv_b") or name.endswith("rec/b_a") \
            or name.endswith("rec/b_i") or name.endswith("rec/lam"):
        return stacked("model")
    # --- norms, gates, everything 1-D-ish: replicate --------------------------
    return P()


def param_shardings(params_shape, cfg: ModelConfig, mesh: Mesh):
    """NamedSharding pytree matching a params (shape) pytree."""
    f = fsdp_axes(mesh)
    # NOTE (§Perf iteration H, REFUTED): when num_heads doesn't divide the
    # model axis (starcoder2: 36 heads / 16 ranks) the flat (H*hd)
    # projection shards across head boundaries and GSPMD all-reduces full
    # (B,H,T,T) attention scores (3 x 144 GiB on train_4k). Forcing
    # attention replication over "model" removes the all-reduce but
    # multiplies the attention memory term ~3x (score temps unsharded) —
    # measured strictly worse. Proper fix is a TP degree that divides the
    # head count (mesh choice) or padding heads; kept as deployment
    # guidance, not forced here.

    def one(path, leaf):
        name = _path_str(path)
        spec = _spec_for(name, leaf.ndim, cfg, f)
        spec = _validate(spec, leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def _validate(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop axis assignments that don't divide the dim (e.g. 36 heads % 16)."""
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        for a in axes:
            size *= _axis_size(mesh, a)
        out.append(ax if dim % size == 0 else None)
    return P(*out)


# ---------------------------------------------------------------------------
# activations / caches


def batch_spec(mesh: Mesh) -> P:
    f = data_axes(mesh)
    return P(f if len(f) > 1 else f[0])


def batch_shardings(batch_shape: Dict[str, Any], cfg: ModelConfig,
                    mesh: Mesh):
    """Shard every batch leaf's leading (batch) dim over the FSDP axes."""
    bs = batch_spec(mesh)

    def one(leaf):
        spec = P(*(tuple(bs) + (None,) * (leaf.ndim - 1)))
        spec = _validate(spec, leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree.map(one, batch_shape)


def kv_head_axes(mesh: Mesh, kv: int, hd: int):
    """Which cache axis takes "model": kv-heads when they divide the axis,
    head_dim as the fallback, else replicate (DESIGN §5; the same rule
    `core.memory_model.kv_shard_factor` applies jax-free)."""
    m = _axis_size(mesh, "model")
    if kv % m == 0:
        return "model", None
    if hd % m == 0:
        return None, "model"
    return None, None


def cache_shardings(cache_shape, cfg: ModelConfig, mesh: Mesh,
                    seq_shard: bool = False):
    """KV/state cache shardings.

    Default: batch over FSDP, kv-heads (or head_dim fallback) over "model".
    seq_shard=True (batch=1 long-context decode): the cache sequence axis is
    sharded over "data" instead — distributed flash-decode.
    """
    f = data_axes(mesh)
    fs = f if len(f) > 1 else f[0]

    def one(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        if name in ("k", "v", "cross_k", "cross_v"):
            # (L, B, S, KV, hd)
            kv_ax, hd_ax = kv_head_axes(mesh, shape[3], shape[4])
            if seq_shard and name in ("k", "v"):
                spec = P(None, None, "data", kv_ax, hd_ax)
            else:
                spec = P(None, fs, None, kv_ax, hd_ax)
        elif name == "pos":
            spec = P(None, "data") if seq_shard else P(fs, None)
        elif name == "conv":                       # (L, B, W-1, ch)
            spec = P(None, None if seq_shard else fs, None, "model")
        elif name == "rec":                        # (L, B, w)
            spec = P(None, None if seq_shard else fs, "model")
        elif name == "ssm":                        # (L, B, H, P, N)
            spec = P(None, None if seq_shard else fs, None, None, None)
        else:
            spec = P()
        spec = _validate(spec, shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def maybe_constrain(x, *spec):
    """with_sharding_constraint that no-ops outside a mesh context and drops
    axes the ambient mesh doesn't have — lets model code carry sharding
    hints without binding to a mesh (single-device tests unaffected)."""
    get_mesh = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_mesh is None:
        # jax < 0.5: fall back to the thread-local physical mesh context
        env = getattr(jax.interpreters.pxla, "thread_resources", None)
        m = getattr(env, "env", None) and env.env.physical_mesh
    else:
        m = get_mesh()
    if m is None or getattr(m, "empty", True):
        return x
    names = set(m.axis_names)
    clean = []
    for ax in spec:
        if ax is None:
            clean.append(None)
        elif isinstance(ax, tuple):
            keep = tuple(a for a in ax if a in names)
            clean.append(keep if keep else None)
        else:
            clean.append(ax if ax in names else None)
    # drop axes that don't divide the dim
    final = []
    for dim, ax in zip(x.shape, clean):
        if ax is None:
            final.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        for a in axes:
            size *= m.shape[a]
        final.append(ax if dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(x, P(*final))


def decode_input_shardings(cfg: ModelConfig, mesh: Mesh, batch: int):
    """(tokens (B,), seq_lens (B,)) shardings for serve_step."""
    f = data_axes(mesh)
    fs = f if len(f) > 1 else f[0]
    total = 1
    for a in f:
        total *= _axis_size(mesh, a)
    spec = P(fs) if batch % total == 0 else P()
    return NamedSharding(mesh, spec)


# ---------------------------------------------------------------------------
# mesh-sharded serving (DESIGN §12)


def serve_param_shardings(params, cfg: ModelConfig, mesh: Mesh):
    """Serving twin of `param_shardings`: the same §5 name-based rules,
    with every FSDP/data axis replaced by replication. Serving carries no
    optimizer state, so params replicate over ("pod",) "data" (plain data
    parallelism) and shard over "model" only — tensor parallelism
    (DESIGN §12). Works on concrete params or a shape pytree."""

    def strip(ax):
        if isinstance(ax, tuple):
            keep = tuple(a for a in ax if a == "model")
            return keep[0] if keep else None
        return ax if ax == "model" else None

    def one(path, leaf):
        name = _path_str(path)
        spec = _spec_for(name, leaf.ndim, cfg, ("data",))
        spec = P(*(strip(ax) for ax in spec))
        return NamedSharding(mesh, _validate(spec, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, params)


def serve_cache_shardings(cache, cfg: ModelConfig, mesh: Mesh):
    """Serving-cache shardings over the "model" axis (DESIGN §12).

    Covers both layouts with one rule set — the leading axes differ but
    the trailing (KV, hd) axes are shared:

      * paged pools     k/v (L, NB, bs, KV, hd), pos (NB, bs)
      * contiguous rows k/v (L, B, S, KV, hd),   pos (B, S)
      * cross-KV        (Lc, slots, enc_len, KV, hd)

    K/V shard on kv-heads ("model"), head_dim fallback (`kv_head_axes`);
    the pos map and slot bookkeeping replicate; per-slot recurrent state
    shards on its channel axis when divisible. Batch/block axes stay
    unsharded — serving batches are bucketized and dynamic, so rows
    replicate over "data"."""

    def one(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        if name in ("k", "v", "cross_k", "cross_v"):
            kv_ax, hd_ax = kv_head_axes(mesh, shape[-2], shape[-1])
            spec = P(*((None,) * (leaf.ndim - 2) + (kv_ax, hd_ax)))
        elif name == "conv":                       # (L, slots, W-1, ch)
            spec = P(None, None, None, "model")
        elif name == "rec":                        # (L, slots, w)
            spec = P(None, None, "model")
        else:                                      # pos / ssm / misc
            spec = P()
        return NamedSharding(mesh, _validate(spec, shape, mesh))

    return jax.tree_util.tree_map_with_path(one, cache)


# The engine's ambient serving mesh (DESIGN §12): set around every jit'd
# serving step so model code (layers.self_attention_paged) can route the
# paged flash-decode kernel through its shard_map wrapper. A module slot,
# not a Mesh context: training meshes must NOT trigger the serving path.
_SERVING_MESH = None


def set_serving_mesh(mesh):
    """Install `mesh` as the ambient serving mesh; returns the previous
    value so callers can restore it (engines with and without a mesh can
    interleave in one process)."""
    global _SERVING_MESH
    prev = _SERVING_MESH
    _SERVING_MESH = mesh
    return prev


def serving_mesh():
    return _SERVING_MESH


def serving_model_axis() -> int:
    """Size of the ambient serving mesh's "model" axis (1 = no TP)."""
    m = _SERVING_MESH
    if m is None or "model" not in m.axis_names:
        return 1
    return int(m.shape["model"])
