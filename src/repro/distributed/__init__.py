from repro.distributed.sharding import (param_shardings,  # noqa: F401
                                        batch_shardings, cache_shardings,
                                        serve_cache_shardings,
                                        serve_param_shardings)
