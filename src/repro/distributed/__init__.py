from repro.distributed.sharding import (param_shardings,  # noqa: F401
                                        batch_shardings, cache_shardings)
