"""Analytic step-latency model for the discrete-event simulator.

tau_step for a fused step = fixed scheduler overhead
                          + weight-read time (memory-bound floor)
                          + per-row marginal cost (KV read + decode FLOPs)
                          + prefill-chunk FLOPs (if PD fusion packs any)

This produces the paper's observed shape: D(b) ~ c0 + c1*b (linear, Fig 3)
and Phi(b) = b / tau(b) concave increasing. Hardware profiles cover the
paper's GPU-class deployments and the TPU v5e target; the `paper-fig3`
profile is calibrated so LLaMA3-70B matches Fig 3's anchor points
(b=100 -> ~50 ms, ~2000 tok/s; b=230 -> ~80 ms, ~2700 tok/s).
"""
from __future__ import annotations

import dataclasses

from repro.config.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    name: str
    chips: int
    flops_per_chip: float          # bf16 FLOP/s
    hbm_bw_per_chip: float         # B/s
    hbm_per_chip: float            # bytes
    step_overhead_ms: float = 15.0  # scheduler + launch + sampling
    # host-side share of step_overhead_ms (DESIGN §14): admission, lane
    # packing, block-table edits, sampling readback — the portion the async
    # dispatch-ahead loop can overlap with the in-flight device step. The
    # remainder of tau_step is device time. Must be <= step_overhead_ms.
    host_overhead_ms: float = 0.0
    parallel_eff: float = 0.85     # TP scaling efficiency
    # host<->device interconnect per chip (PCIe gen4 x16-class), the KV
    # swap path's bandwidth (DESIGN §11)
    pcie_bw_per_chip: float = 24e9


PROFILES = {
    "a100x8": HardwareProfile("a100x8", 8, 312e12, 2.039e12, 80e9,
                              step_overhead_ms=20.0, host_overhead_ms=8.0),
    "h800x8": HardwareProfile("h800x8", 8, 989e12, 3.35e12, 80e9,
                              step_overhead_ms=15.0, host_overhead_ms=6.0),
    "v5e-16": HardwareProfile("v5e-16", 16, 197e12, 819e9, 16e9,
                              step_overhead_ms=5.0, host_overhead_ms=2.0),
    "v5e-256": HardwareProfile("v5e-256", 256, 197e12, 819e9, 16e9,
                               step_overhead_ms=5.0, host_overhead_ms=2.0),
    # calibrated to the paper's Fig 3 anchors (LLaMA3-70B deployment)
    "paper-fig3": HardwareProfile("paper-fig3", 8, 120e12, 1.1e12, 64e9,
                                  step_overhead_ms=28.0,
                                  host_overhead_ms=10.0, parallel_eff=0.8),
}


@dataclasses.dataclass
class CostModel:
    cfg: ModelConfig
    hw: HardwareProfile
    dtype_bytes: int = 2
    # optional calibrated-linear override: tau = c0 + c1*(rows + prefill_toks).
    # Used by the paper-reproduction benchmarks where the paper's deployment
    # (vLLM-on-GPU, Fig 3) is flatter/steeper than the pure roofline law.
    c0_ms: float = 0.0
    c1_ms: float = 0.0

    def __post_init__(self):
        hwp = self.hw
        self.total_flops = hwp.chips * hwp.flops_per_chip * hwp.parallel_eff
        self.total_bw = hwp.chips * hwp.hbm_bw_per_chip * hwp.parallel_eff
        self.n_active = self.cfg.active_param_count()
        self.weight_bytes = self.n_active * self.dtype_bytes
        self.kv_bpt = self.cfg.kv_bytes_per_token(self.dtype_bytes)

    # -- components (seconds) ------------------------------------------------
    def weight_read_s(self) -> float:
        return self.weight_bytes / self.total_bw

    def decode_row_s(self, ctx_len: float) -> float:
        kv_read = ctx_len * self.kv_bpt / self.total_bw
        compute = 2.0 * self.n_active / self.total_flops
        return kv_read + compute

    def prefill_tokens_s(self, n_tokens: int, ctx_len: float) -> float:
        if n_tokens <= 0:
            return 0.0
        dense = 2.0 * self.n_active * n_tokens / self.total_flops
        # quadratic attention term (scores against ctx)
        att = 0.0
        if self.kv_bpt:
            att_flops = 4.0 * self.cfg.num_layers * self.cfg.d_model \
                * n_tokens * ctx_len
            att = att_flops / self.total_flops
        return dense + att

    # -- two-tier KV swap (DESIGN §11) ----------------------------------------
    def swap_bytes(self, n_blocks: int, block_size: int) -> int:
        """KV bytes held by n_blocks pool blocks (one direction's payload)."""
        return n_blocks * block_size * self.kv_bpt

    def pcie_s(self, n_blocks: int, block_size: int) -> float:
        """One-way host<->device transfer time for n_blocks KV blocks."""
        bw = self.hw.chips * self.hw.pcie_bw_per_chip
        return self.swap_bytes(n_blocks, block_size) / bw

    def reprefill_s(self, context_tokens: int) -> float:
        """Time to rebuild a victim's KV from scratch: a full re-prefill of
        its context (mean attention depth ~ context/2)."""
        return self.prefill_tokens_s(context_tokens, context_tokens / 2.0)

    def swap_beats_recompute(self, n_blocks: int, block_size: int,
                             context_tokens: int) -> bool:
        """The preemption crossover (DESIGN §11): swap the victim when the
        round-trip PCIe time for its blocks undercuts re-prefilling its
        context — trade interconnect bandwidth for re-prefill FLOPs."""
        if self.kv_bpt == 0:
            return False
        return 2.0 * self.pcie_s(n_blocks, block_size) \
            < self.reprefill_s(context_tokens)

    # -- the step law ---------------------------------------------------------
    def tau_step_s(self, decode_batch: int, mean_ctx: float,
                   prefill_tokens: int = 0, prefill_ctx: float = 0.0) -> float:
        if self.c1_ms:
            return (self.c0_ms + self.c1_ms *
                    (decode_batch + prefill_tokens)) / 1e3
        t = self.hw.step_overhead_ms / 1e3
        t += self.weight_read_s()
        t += decode_batch * self.decode_row_s(mean_ctx)
        t += self.prefill_tokens_s(prefill_tokens, prefill_ctx or mean_ctx)
        return t

    def split_host_device(self, tau_s: float) -> "tuple[float, float]":
        """Split one interval's tau_step into (host_s, device_s) — the
        host-vs-device interval split (DESIGN §14). Host time is the
        profile's host_overhead_ms share of the fixed step overhead
        (clamped to the interval: a tiny calibrated tau can undercut it);
        everything else — weight reads, KV reads, FLOPs — is device time.
        host_s + device_s == tau_s always, so the sync-mode clock is
        unchanged; the async sim advances by max(host, device) instead."""
        host = min(self.hw.host_overhead_ms / 1e3, tau_s)
        return host, tau_s - host

    def tau_step_ms(self, decode_batch: int, mean_ctx: float,
                    prefill_tokens: int = 0, prefill_ctx: float = 0.0) -> float:
        return 1e3 * self.tau_step_s(decode_batch, mean_ctx, prefill_tokens,
                                     prefill_ctx)

    # -- memory budget ---------------------------------------------------------
    def kv_pool_bytes(self, activation_frac: float = 0.1) -> int:
        total = self.hw.chips * self.hw.hbm_per_chip
        params = self.cfg.param_count() * self.dtype_bytes
        budget = total * (1 - activation_frac) - params
        return max(int(budget), 0)
