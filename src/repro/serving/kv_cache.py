"""Block-granular KV pool accounting (vLLM-style allocator).

The bottom layer of the controller stack (DESIGN §1). On TPU the physical
cache is a contiguous padded tensor per batch slot — decode buckets plus
the PD-fusion prefill lanes (DESIGN §3, §6); paging lives at the
*allocator* level: this class tracks block ownership so the scheduler sees
the same free-token signal a paged GPU allocator would provide, and
admission control + preemption use it. The block table per request is
maintained (host-side) so the accounting is faithful to the paper's vLLM
deployment.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List


@dataclasses.dataclass
class BlockManager:
    total_tokens: int                 # eta: pool capacity in tokens
    block_size: int = 16

    def __post_init__(self):
        self.num_blocks = self.total_tokens // self.block_size
        self._free: List[int] = list(range(self.num_blocks))
        self.tables: Dict[int, List[int]] = {}     # rid -> block ids

    # -- queries ------------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def free_tokens(self) -> int:
        return self.free_blocks * self.block_size

    def used_tokens_of(self, rid: int) -> int:
        return len(self.tables.get(rid, ())) * self.block_size

    def blocks_needed(self, cur_tokens: int, new_tokens: int, rid: int) -> int:
        have = len(self.tables.get(rid, ()))
        need = -(-(cur_tokens + new_tokens) // self.block_size)  # ceil div
        return max(need - have, 0)

    def can_allocate(self, cur_tokens: int, new_tokens: int, rid: int) -> bool:
        return self.blocks_needed(cur_tokens, new_tokens, rid) <= self.free_blocks

    # -- mutations ------------------------------------------------------------
    def allocate(self, rid: int, cur_tokens: int, new_tokens: int) -> bool:
        n = self.blocks_needed(cur_tokens, new_tokens, rid)
        if n > self.free_blocks:
            return False
        tbl = self.tables.setdefault(rid, [])
        for _ in range(n):
            tbl.append(self._free.pop())
        return True

    def free(self, rid: int) -> None:
        for b in self.tables.pop(rid, ()):
            self._free.append(b)

    def reset(self) -> None:
        self._free = list(range(self.num_blocks))
        self.tables.clear()
