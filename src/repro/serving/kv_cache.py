"""Block-granular KV pool accounting (vLLM-style allocator).

The bottom layer of the controller stack (DESIGN §1). With the physically
paged cache (`ServeConfig.paged_kv`, DESIGN §9) the per-request block
tables kept here ARE the storage map: token position p of request r lives
in physical pool block `tables[r][p // block_size]`, and the engine ships
the tables to the paged decode kernel each step. With the legacy
contiguous cache (DESIGN §3) the same accounting runs as bookkeeping only,
so the scheduler sees the identical free-token signal either way.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List


@dataclasses.dataclass
class BlockManager:
    total_tokens: int                 # eta: pool capacity in tokens
    block_size: int = 16

    def __post_init__(self):
        self.num_blocks = self.total_tokens // self.block_size
        self._free: List[int] = list(range(self.num_blocks))
        self.tables: Dict[int, List[int]] = {}     # rid -> block ids

    # -- queries ------------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def free_tokens(self) -> int:
        return self.free_blocks * self.block_size

    def used_tokens_of(self, rid: int) -> int:
        return len(self.tables.get(rid, ())) * self.block_size

    def blocks_needed(self, cur_tokens: int, new_tokens: int, rid: int) -> int:
        have = len(self.tables.get(rid, ()))
        need = -(-(cur_tokens + new_tokens) // self.block_size)  # ceil div
        return max(need - have, 0)

    def can_allocate(self, cur_tokens: int, new_tokens: int, rid: int) -> bool:
        return self.blocks_needed(cur_tokens, new_tokens, rid) <= self.free_blocks

    def admission_verdict(self, blocks_needed: int,
                          max_blocks: int = 0) -> str:
        """Shared engine/sim admission gate (DESIGN §7): the vLLM-style 1%
        free-block watermark plus the unservable-request bound.

        Returns "admit" (enough pool headroom), "defer" (watermark refusal
        that a future pool state can satisfy), or "reject" (no pool state
        can ever satisfy it — larger than the pool minus the watermark, or
        than `max_blocks`, the per-request block-table width, if given)."""
        watermark = max(self.num_blocks // 100, 1)
        if self.free_blocks - blocks_needed >= watermark:
            if max_blocks and blocks_needed > max_blocks:
                return "reject"
            return "admit"
        cap = self.num_blocks - watermark
        if max_blocks:
            cap = min(cap, max_blocks)
        return "reject" if blocks_needed > cap else "defer"

    # -- mutations ------------------------------------------------------------
    def allocate(self, rid: int, cur_tokens: int, new_tokens: int) -> bool:
        n = self.blocks_needed(cur_tokens, new_tokens, rid)
        if n > self.free_blocks:
            return False
        tbl = self.tables.setdefault(rid, [])
        for _ in range(n):
            tbl.append(self._free.pop())
        return True

    def free(self, rid: int) -> List[int]:
        """Release a request's blocks; returns the freed physical ids so the
        paged engine can clear their position-pool rows (DESIGN §9)."""
        freed = self.tables.pop(rid, [])
        self._free.extend(freed)
        return freed

    def reset(self) -> None:
        self._free = list(range(self.num_blocks))
        self.tables.clear()
