"""Block-granular KV pool accounting (vLLM-style allocator).

The bottom layer of the controller stack (DESIGN §1). With the physically
paged cache (`ServeConfig.paged_kv`, DESIGN §9) the per-request block
tables kept here ARE the storage map: token position p of request r lives
in physical pool block `tables[r][p // block_size]`, and the engine ships
the tables to the paged decode kernel each step. With the legacy
contiguous cache (DESIGN §3) the same accounting runs as bookkeeping only,
so the scheduler sees the identical free-token signal either way.

With `prefix_cache=True` (DESIGN §10) the allocator grows vLLM-style
automatic prefix sharing on top of the paged pool: per-block refcounts, a
content-hash → block-id index over *full* prompt blocks, and `free()`
becomes a decref — blocks whose refcount hits zero stay resident as an
evictable LRU cache until the free list runs dry. Admission maps matched
blocks into a new request's table with zero copies and prefills only the
unmatched suffix.

With `swap_space_blocks > 0` (DESIGN §11) the allocator gains a second,
host-side block pool: preemption may `swap_out` a victim (its device table
becomes a host-block swap ledger; the engine copies the pool rows over
PCIe) instead of discarding its KV for recompute, and `swap_in` restores
the ledger onto fresh device blocks when admission drains the swapped
queue.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
from typing import Dict, List, Optional, Sequence, Tuple


def prefix_cache_supported(cfg) -> bool:
    """Prefix sharing reuses attention K/V blocks only. Families carrying
    per-slot sequential state (SSM/RG-LRU conv state, enc-dec/VLM cross-KV)
    cannot skip prefill of a shared prefix — their state depends on every
    prefix token — and windowed attention evicts the very blocks a later
    request would want to share (DESIGN §10)."""
    from repro.config.base import ArchFamily, AttentionKind
    return (cfg.family in (ArchFamily.DENSE, ArchFamily.MOE)
            and cfg.attention == AttentionKind.FULL)


def swap_supported(cfg) -> bool:
    """Host-offload swapping moves paged K/V pool blocks only (DESIGN §11).
    Families whose per-request state lives outside the block pools
    (SSM/RG-LRU conv and recurrent state, enc-dec/VLM cross-KV) would need
    that state saved and restored too, so swap is gated to the same
    attention-only families as prefix sharing."""
    return prefix_cache_supported(cfg)


@dataclasses.dataclass
class BlockManager:
    total_tokens: int                 # eta: pool capacity in tokens
    block_size: int = 16
    prefix_cache: bool = False        # ref-counted prefix sharing (DESIGN §10)
    swap_space_blocks: int = 0        # host-side swap pool size (DESIGN §11)

    def __post_init__(self):
        self.num_blocks = self.total_tokens // self.block_size
        self._free: List[int] = list(range(self.num_blocks))
        self.tables: Dict[int, List[int]] = {}     # rid -> block ids
        # two-tier swap space (DESIGN §11): a second, host-side block pool.
        # A swapped-out rid's device table becomes a *swap ledger* of host
        # block ids, restored verbatim (onto fresh device blocks) by
        # swap_in. Host blocks are pure accounting here; the engine owns
        # the actual host-RAM copies of the pool contents.
        self._swap_free: List[int] = list(range(self.swap_space_blocks))
        self.swapped_tables: Dict[int, List[int]] = {}   # rid -> host ids
        self.swap_out_blocks = 0      # cumulative blocks copied out
        self.swap_in_blocks = 0       # cumulative blocks copied back
        self.swapped_peak = 0         # peak concurrently swapped requests
        # prefix-sharing state (DESIGN §10); maintained (cheaply) even with
        # prefix_cache=False so the invariants below hold unconditionally
        self.ref: Dict[int, int] = {}              # block -> refcount (>=1)
        self._hash_of: Dict[int, bytes] = {}       # block -> registered hash
        self._index: Dict[bytes, int] = {}         # content hash -> block
        # ref==0 registered blocks, resident + evictable, LRU order
        self._cached: "collections.OrderedDict[int, None]" = \
            collections.OrderedDict()
        # per-rid commit cursor: (#full blocks hashed, chain hash)
        self._commit: Dict[int, Tuple[int, bytes]] = {}
        # blocks evicted-for-reuse whose pos-pool rows the paged engine
        # must clear before their new tenant's first step (DESIGN §10)
        self._released: List[int] = []
        # shadow-table epoch (DESIGN §14): while an epoch is open — i.e.
        # while a dispatched device step is still in flight — blocks freed
        # by scheduling edits are parked here instead of the free list, so
        # the allocator hands them out only after every other free block
        # (oldest-first), and `shadow_commit` at step retirement returns
        # them to normal circulation. All pool-headroom queries count them
        # as free, so admission/grow decisions are epoch-invariant: the
        # epoch changes WHICH block ids are reused, never whether an
        # allocation succeeds.
        self._deferred: List[int] = []
        self._epoch_open = False
        self._shadow_snap = None
        self.prefix_hit_tokens = 0     # tokens served from shared blocks
        self.prefix_query_tokens = 0   # prompt tokens probed at admission
        self.cache_evictions = 0       # cached blocks reclaimed for reuse
        self.cow_copies = 0            # copy-on-write block duplications

    # -- queries ------------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        """Reclaimable blocks: truly free + epoch-deferred + evictable
        cached (ref == 0). This is the controller's free signal — cached
        blocks are reclaimed on demand by `allocate` and deferred blocks
        re-enter at `shadow_commit` or as a last resort, so admission/grow
        headroom must count both (DESIGN §10/§14)."""
        return len(self._free) + len(self._deferred) + len(self._cached)

    @property
    def free_tokens(self) -> int:
        return self.free_blocks * self.block_size

    @property
    def physical_free_blocks(self) -> int:
        """Blocks holding no resident content at all (epoch-deferred
        blocks are freed content-wise; they are merely reuse-parked)."""
        return len(self._free) + len(self._deferred)

    @property
    def cached_blocks(self) -> int:
        """Resident-but-unreferenced blocks (the evictable prefix cache)."""
        return len(self._cached)

    @property
    def logical_used_tokens(self) -> int:
        """Per-request footprints summed — shared blocks counted once per
        referencing request (what a no-sharing allocator would charge)."""
        return sum(len(t) for t in self.tables.values()) * self.block_size

    @property
    def physical_used_tokens(self) -> int:
        """Deduped usage: distinct referenced blocks (DESIGN §10)."""
        return (self.num_blocks - self.free_blocks) * self.block_size

    @property
    def prefix_hit_rate(self) -> float:
        return self.prefix_hit_tokens / max(self.prefix_query_tokens, 1)

    @property
    def host_free_blocks(self) -> int:
        """Unused blocks in the host-side swap pool (DESIGN §11)."""
        return len(self._swap_free)

    @property
    def swapped_blocks(self) -> int:
        """Host blocks currently holding swapped-out KV state."""
        return sum(len(t) for t in self.swapped_tables.values())

    @property
    def swapped_tokens(self) -> int:
        """Device tokens the swapped backlog will re-claim on swap-in —
        the swap-pressure term Alg 1 subtracts from its capacity
        (DESIGN §11)."""
        return self.swapped_blocks * self.block_size

    def used_tokens_of(self, rid: int) -> int:
        return len(self.tables.get(rid, ())) * self.block_size

    def blocks_needed(self, cur_tokens: int, new_tokens: int, rid: int) -> int:
        have = len(self.tables.get(rid, ()))
        need = -(-(cur_tokens + new_tokens) // self.block_size)  # ceil div
        return max(need - have, 0)

    def can_allocate(self, cur_tokens: int, new_tokens: int, rid: int) -> bool:
        return self.blocks_needed(cur_tokens, new_tokens, rid) <= self.free_blocks

    def admission_verdict(self, blocks_needed: int,
                          max_blocks: int = 0) -> str:
        """Shared engine/sim admission gate (DESIGN §7): the vLLM-style 1%
        free-block watermark plus the unservable-request bound.

        Returns "admit" (enough pool headroom), "defer" (watermark refusal
        that a future pool state can satisfy), or "reject" (no pool state
        can ever satisfy it — larger than the pool minus the watermark, or
        than `max_blocks`, the per-request block-table width, if given)."""
        watermark = max(self.num_blocks // 100, 1)
        if self.free_blocks - blocks_needed >= watermark:
            if max_blocks and blocks_needed > max_blocks:
                return "reject"
            return "admit"
        cap = self.num_blocks - watermark
        if max_blocks:
            cap = min(cap, max_blocks)
        return "reject" if blocks_needed > cap else "defer"

    # -- prefix sharing (DESIGN §10) ------------------------------------------
    _CHAIN_ROOT = b""

    @staticmethod
    def _chain(prev: bytes, block_tokens: Sequence[int]) -> bytes:
        """Content hash of one full block, chained on the whole prefix so a
        block matches only when every token before it matched too. sha256,
        not the builtin hash(): int-tuple hashes ignore PYTHONHASHSEED, so
        a 64-bit collision would be deterministic and adversarially
        constructible — and a collision here maps another prompt's physical
        KV into the request (the vLLM content-hash lesson)."""
        h = hashlib.sha256(prev)
        h.update(",".join(map(str, block_tokens)).encode())
        return h.digest()

    def _pop_block(self) -> Optional[int]:
        """Take a physical block: prefer the free list, then epoch-deferred
        blocks (oldest first — the blocks a possibly in-flight step just
        read are reused last, DESIGN §14), else evict the least-recently-
        used cached block (deregistering its content and queueing it for a
        pos-row clear). Deferred-before-cached keeps the eviction count
        identical to the epoch-free synchronous loop, where deferred
        blocks would simply sit on the free list."""
        if self._free:
            return self._free.pop()
        if self._deferred:
            return self._deferred.pop(0)
        if self._cached:
            b, _ = self._cached.popitem(last=False)   # LRU end
            h = self._hash_of.pop(b, None)
            if h is not None and self._index.get(h) == b:
                del self._index[h]
            self._released.append(b)
            self.cache_evictions += 1
            return b
        return None

    def _push_free(self, b: int) -> None:
        """Return a block to circulation: parked in the epoch's deferred
        set while a shadow epoch is open (an in-flight device step may
        still be reading it), straight to the free list otherwise."""
        (self._deferred if self._epoch_open else self._free).append(b)

    # -- shadow-table epochs (DESIGN §14) --------------------------------------
    def shadow_begin(self) -> None:
        """Open a shadow epoch covering one in-flight device step: blocks
        freed until the matching `shadow_commit` are parked (reused only
        after every other free block), and the full allocator state is
        snapshotted so `shadow_rollback` can restore it. Headroom queries
        (`free_blocks`, `admission_verdict`, `can_allocate`) count parked
        blocks as free, so scheduling decisions match the synchronous loop
        exactly — the epoch only biases WHICH ids are reused."""
        if self._epoch_open:
            raise RuntimeError("shadow epoch already open — commit or "
                               "roll back the previous step first")
        self._epoch_open = True
        self._shadow_snap = dict(
            free=list(self._free), deferred=list(self._deferred),
            tables={r: list(t) for r, t in self.tables.items()},
            ref=dict(self.ref), hash_of=dict(self._hash_of),
            index=dict(self._index),
            cached=collections.OrderedDict(self._cached),
            commit=dict(self._commit), released=list(self._released),
            swap_free=list(self._swap_free),
            swapped_tables={r: list(t)
                            for r, t in self.swapped_tables.items()},
            counters=(self.swap_out_blocks, self.swap_in_blocks,
                      self.swapped_peak, self.prefix_hit_tokens,
                      self.prefix_query_tokens, self.cache_evictions,
                      self.cow_copies))

    def shadow_commit(self) -> None:
        """Seal the epoch at step retirement: the step's reads are done, so
        parked blocks rejoin the free list (in free order) and the rollback
        snapshot is dropped. Safe to call with no epoch open (the first
        retirement of a run) — it just flushes nothing."""
        self._free.extend(self._deferred)
        self._deferred = []
        self._epoch_open = False
        self._shadow_snap = None

    def shadow_rollback(self) -> None:
        """Abandon every table edit since `shadow_begin` and restore the
        allocator to that snapshot — the recovery path when a dispatched
        step must be discarded (and the invariant anchor the hypothesis
        suite pins: begin -> arbitrary mutations -> rollback is a no-op)."""
        if not self._epoch_open:
            raise RuntimeError("no shadow epoch open to roll back")
        s = self._shadow_snap
        self._free = s["free"]
        self._deferred = s["deferred"]
        self.tables = s["tables"]
        self.ref = s["ref"]
        self._hash_of = s["hash_of"]
        self._index = s["index"]
        self._cached = s["cached"]
        self._commit = s["commit"]
        self._released = s["released"]
        self._swap_free = s["swap_free"]
        self.swapped_tables = s["swapped_tables"]
        (self.swap_out_blocks, self.swap_in_blocks, self.swapped_peak,
         self.prefix_hit_tokens, self.prefix_query_tokens,
         self.cache_evictions, self.cow_copies) = s["counters"]
        self._epoch_open = False
        self._shadow_snap = None

    def acquire_prefix(self, rid: int, token_ids: Sequence[int]) -> int:
        """Match `token_ids` against the prefix index and map every shared
        full block into `rid`'s (empty) table with zero copies — resurrect
        cached blocks, bump refcounts. Returns the number of cached tokens;
        the caller prefills only the suffix. On a FULL-prompt hit the last
        matched block is demoted (left unmatched) so the suffix is never
        empty: the engine must still compute last-position logits to sample
        the first output token, and re-prefilling that whole block keeps
        shared blocks write-free (no COW on the hot path). Roll back an
        admission refusal with `free(rid)`; count hit-rate telemetry with
        `note_prefix_query` only once the request is actually admitted."""
        if not self.prefix_cache or self.tables.get(rid):
            return 0
        bs = self.block_size
        matched: List[int] = []
        h = self._CHAIN_ROOT
        for k in range(len(token_ids) // bs):
            nh = self._chain(h, token_ids[k * bs:(k + 1) * bs])
            b = self._index.get(nh)
            if b is None:
                break
            matched.append(b)
            h = nh
        if matched and len(matched) * bs == len(token_ids):
            matched.pop()              # full hit: demote the tail block
        if not matched:
            return 0
        tbl = self.tables.setdefault(rid, [])
        for b in matched:
            if b in self._cached:
                del self._cached[b]    # resurrect from the evictable cache
            self.ref[b] = self.ref.get(b, 0) + 1
            tbl.append(b)
        self._commit[rid] = (len(matched), self._hash_of[matched[-1]])
        return len(matched) * bs

    def note_prefix_query(self, n_query: int, n_hit: int) -> None:
        """Hit-rate telemetry, counted on successful admission only (a
        deferred request re-probes every interval and would skew the rate —
        and break engine-vs-sim hit-rate parity, DESIGN §10)."""
        self.prefix_query_tokens += n_query
        self.prefix_hit_tokens += n_hit

    def commit_prefill(self, rid: int, token_ids: Sequence[int],
                       n_tokens: int) -> None:
        """Register every full block of `token_ids[:n_tokens]` whose
        content is now written to the pool (call AFTER the prefill chunk
        lands). First writer wins: content already indexed elsewhere leaves
        this request's copy private."""
        if not self.prefix_cache:
            return
        bs = self.block_size
        tbl = self.tables.get(rid, ())
        k, h = self._commit.get(rid, (0, self._CHAIN_ROOT))
        n_tokens = min(n_tokens, len(token_ids))
        while (k + 1) * bs <= n_tokens and k < len(tbl):
            h = self._chain(h, token_ids[k * bs:(k + 1) * bs])
            b = tbl[k]
            if h not in self._index and b not in self._hash_of:
                self._index[h] = b
                self._hash_of[b] = h
            k += 1
        self._commit[rid] = (k, h)

    def cow_range(self, rid: int, start_pos: int,
                  end_pos: int) -> List[Tuple[int, int]]:
        """Copy-on-write guard for a token-position write range: any shared
        (refcount > 1) block about to be written is replaced by a private
        copy in the table. Returns [(src, dst)] pairs whose pool contents
        the paged engine must copy (DESIGN §10). Suffix-aligned mapping +
        full-hit demotion keep this empty on the steady-state path; it
        exists so a shared block can never be clobbered by construction."""
        if not self.prefix_cache or end_pos <= start_pos:
            return []
        tbl = self.tables.get(rid)
        if not tbl:
            return []
        bs = self.block_size
        out: List[Tuple[int, int]] = []
        for k in range(start_pos // bs, min(-(-end_pos // bs), len(tbl))):
            b = tbl[k]
            if self.ref.get(b, 0) <= 1:
                continue
            nb = self._pop_block()
            if nb is None:
                raise RuntimeError("COW with an exhausted pool: caller must "
                                   "hold free headroom before writing")
            if nb in self._released:
                # the engine is about to copy valid K/V *and pos* into this
                # block — a queued pos-row clear would wipe the copy and
                # mask the whole block from attention
                self._released.remove(nb)
            self.ref[b] -= 1
            self.ref[nb] = 1
            tbl[k] = nb
            # the private copy diverges from the registered content hash
            out.append((b, nb))
            self.cow_copies += 1
        return out

    def take_released(self) -> List[int]:
        """Drain blocks evicted-for-reuse since the last call; the paged
        engine clears their pos-pool rows so the new tenant never sees the
        cached tenant's stale positions (DESIGN §10)."""
        out, self._released = self._released, []
        return out

    # -- host-offload swap (DESIGN §11) ----------------------------------------
    def can_swap_out(self, rid: int, max_blocks: int = 0) -> bool:
        """A victim is swappable when (a) the host pool can hold its whole
        table, (b) none of its blocks is shared — a ref > 1 block's content
        must stay device-resident for its other owners, so shared victims
        fall back to recompute (free() decrefs instead) — and (c) the
        table would still be re-admittable under the §7 watermark (a
        grown-past-capacity victim swapped out could never swap back in)."""
        tbl = self.tables.get(rid)
        if not tbl or len(tbl) > len(self._swap_free):
            return False
        if any(self.ref.get(b, 1) > 1 for b in tbl):
            return False
        return self.admission_verdict(len(tbl), max_blocks) != "reject"

    def swap_out(self, rid: int) -> List[Tuple[int, int]]:
        """Move `rid`'s device blocks to the host pool: the device table
        becomes a swap ledger of host block ids, the device blocks go back
        to the free list, and registered content is deregistered from the
        prefix index (its device copy is gone — same as eviction-for-reuse,
        so the index itself is otherwise untouched, DESIGN §11). Returns
        [(device, host)] copy pairs; the caller must copy pool contents
        (K/V *and* pos rows) to host storage BEFORE reusing the freed
        device blocks."""
        tbl = self.tables.pop(rid)
        pairs: List[Tuple[int, int]] = []
        host: List[int] = []
        for b in tbl:
            self.ref.pop(b, None)
            h = self._hash_of.pop(b, None)
            if h is not None and self._index.get(h) == b:
                del self._index[h]
            hb = self._swap_free.pop()
            pairs.append((b, hb))
            host.append(hb)
            self._push_free(b)
        self.swapped_tables[rid] = host
        self._commit.pop(rid, None)
        self.swap_out_blocks += len(host)
        self.swapped_peak = max(self.swapped_peak, len(self.swapped_tables))
        return pairs

    def can_swap_in(self, rid: int) -> bool:
        return len(self.swapped_tables.get(rid, ())) <= self.free_blocks

    def swap_in(self, rid: int) -> List[Tuple[int, int]]:
        """Restore a swapped-out request onto fresh device blocks (possibly
        evicting prefix-cached blocks, exactly like allocate). Returns
        [(host, device)] copy pairs; the caller copies the host contents
        back into the pool (after draining `take_released`, so a stale pos
        clear can never land on top of the restored rows) and returns the
        host blocks' contents to the swap pool."""
        host = self.swapped_tables.pop(rid)
        tbl = self.tables.setdefault(rid, [])
        pairs: List[Tuple[int, int]] = []
        for hb in host:
            b = self._pop_block()
            self.ref[b] = 1
            tbl.append(b)
            pairs.append((hb, b))
            self._swap_free.append(hb)
        self.swap_in_blocks += len(host)
        return pairs

    # -- mutations ------------------------------------------------------------
    def allocate(self, rid: int, cur_tokens: int, new_tokens: int) -> bool:
        n = self.blocks_needed(cur_tokens, new_tokens, rid)
        if n > self.free_blocks:
            return False
        tbl = self.tables.setdefault(rid, [])
        for _ in range(n):
            b = self._pop_block()
            self.ref[b] = 1
            tbl.append(b)
        return True

    def free(self, rid: int) -> List[int]:
        """Release a request's blocks — a decref under prefix sharing.
        Registered blocks whose refcount hits zero stay resident in the
        evictable LRU cache; the rest go back to the free list. Returns the
        ids actually freed so the paged engine can clear their position-pool
        rows (cached blocks keep theirs — their content must stay readable
        when re-mapped, DESIGN §9/§10)."""
        freed: List[int] = []
        for b in self.tables.pop(rid, []):
            r = self.ref.get(b, 1) - 1
            if r > 0:
                self.ref[b] = r
                continue
            self.ref.pop(b, None)
            if self.prefix_cache and b in self._hash_of:
                self._cached[b] = None          # most-recently-used end
            else:
                self._push_free(b)
                freed.append(b)
        # a finished/cancelled request may still hold a swap ledger
        # (DESIGN §11): its host blocks return to the swap pool
        self._swap_free.extend(self.swapped_tables.pop(rid, ()))
        self._commit.pop(rid, None)
        return freed

    def reset(self) -> None:
        self._free = list(range(self.num_blocks))
        self._deferred = []
        self._epoch_open = False
        self._shadow_snap = None
        self.tables.clear()
        self.ref.clear()
        self._hash_of.clear()
        self._index.clear()
        self._cached.clear()
        self._commit.clear()
        self._released.clear()
        self._swap_free = list(range(self.swap_space_blocks))
        self.swapped_tables.clear()
