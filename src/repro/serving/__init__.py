from repro.serving.request import Request, RequestState  # noqa: F401
from repro.serving.kv_cache import BlockManager  # noqa: F401
