"""Request lifecycle for the serving engine & simulator."""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional


class RequestState(enum.Enum):
    WAITING = "waiting"        # queued, no KV allocated
    PREFILLING = "prefilling"  # chunked prefill in progress
    RUNNING = "running"        # decoding
    PREEMPTED = "preempted"    # evicted; will re-prefill (recompute policy)
    SWAPPED = "swapped"        # KV offloaded to the host pool (DESIGN §11)
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    rid: int
    arrival_time: float
    prompt_tokens: Optional[List[int]] = None   # real engine
    prompt_len: int = 0                          # simulator (len only)
    max_new_tokens: int = 128
    true_output_len: int = 0                     # simulator: sampled a priori

    state: RequestState = RequestState.WAITING
    # set when admission drops the request as unservable (bigger than the
    # pool minus the watermark, or than the block-table width — DESIGN §9);
    # state is FINISHED with no output, this flag tells the two apart
    rejected: bool = False
    prefill_pos: int = 0                         # chunked-prefill progress
    # prefix sharing (DESIGN §10): prompt tokens served from shared blocks
    # at admission — prefill starts at this offset and only the suffix is
    # charged to the chunk budget
    cached_prefix_len: int = 0
    output_tokens: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1                               # engine batch slot
    lane: int = -1                               # PD-fusion prefill lane (DESIGN §6)
    prefill_start_time: float = -1.0             # first prefill chunk (TTFT attribution)
    first_token_time: float = -1.0
    finish_time: float = -1.0
    tbt_samples: List[float] = dataclasses.field(default_factory=list)
    # two-tier swap (DESIGN §11): per-request swap latency accounting
    swap_out_time: float = -1.0                  # pending swap-out timestamp
    swapped_s: float = 0.0                       # total time spent offloaded
    n_swaps: int = 0                             # completed swap round trips
    # per-request goodput SLA verdict (DESIGN §15): stamped once — at
    # retirement in the engine, at finish/rejection in the sim — distinct
    # from the per-step `sla_attainment` window of d_sla_ms
    ttft_ok: bool = False
    tbt_ok: bool = False
    sla_met: bool = False

    def __post_init__(self):
        if self.prompt_tokens is not None and self.prompt_len == 0:
            self.prompt_len = len(self.prompt_tokens)

    @property
    def output_len(self) -> int:
        return len(self.output_tokens) if self.output_tokens else self._sim_outlen

    _sim_outlen: int = 0

    @property
    def context_len(self) -> int:
        return self.prompt_len + max(len(self.output_tokens), self._sim_outlen)

    def sim_emit_token(self):
        self._sim_outlen += 1

    def sim_reset_output(self):
        """Recompute preemption (simulator): the engine regenerates the
        victim's output from scratch on re-admission, so the sim twin
        drops the emitted count to mirror it step-for-step (DESIGN §11)."""
        self._sim_outlen = 0

    def stamp_sla(self, ttft_sla_s: float, tbt_sla_ms: float) -> bool:
        """Stamp the per-request goodput verdict (DESIGN §15).

        TTFT = first_token_time - arrival_time; mean TBT = the decode
        span (finish - first token) over the n-1 inter-token gaps (0 when
        at most one token was produced). A threshold of 0 disables that
        check; rejected (or never-served) requests never meet the SLA.
        Both twins compute the verdict from the same three timestamps, so
        the differential harness can compare them request for request."""
        if self.rejected or self.first_token_time < 0:
            self.ttft_ok = self.tbt_ok = self.sla_met = False
            return False
        ttft = self.first_token_time - self.arrival_time
        self.ttft_ok = ttft_sla_s <= 0 or ttft <= ttft_sla_s
        n_out = max(len(self.output_tokens), self._sim_outlen)
        tbt_ms = 0.0
        if n_out > 1 and self.finish_time >= 0:
            tbt_ms = (self.finish_time - self.first_token_time) \
                / (n_out - 1) * 1e3
        self.tbt_ok = tbt_sla_ms <= 0 or tbt_ms <= tbt_sla_ms
        self.sla_met = self.ttft_ok and self.tbt_ok
        return self.sla_met

    @property
    def done(self) -> bool:
        n_out = max(len(self.output_tokens), self._sim_outlen)
        if self.true_output_len:
            return n_out >= min(self.true_output_len, self.max_new_tokens)
        return n_out >= self.max_new_tokens
