"""Request lifecycle for the serving engine & simulator."""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional


class RequestState(enum.Enum):
    WAITING = "waiting"        # queued, no KV allocated
    PREFILLING = "prefilling"  # chunked prefill in progress
    RUNNING = "running"        # decoding
    PREEMPTED = "preempted"    # evicted; will re-prefill (recompute policy)
    SWAPPED = "swapped"        # KV offloaded to the host pool (DESIGN §11)
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    rid: int
    arrival_time: float
    prompt_tokens: Optional[List[int]] = None   # real engine
    prompt_len: int = 0                          # simulator (len only)
    max_new_tokens: int = 128
    true_output_len: int = 0                     # simulator: sampled a priori

    state: RequestState = RequestState.WAITING
    # set when admission drops the request as unservable (bigger than the
    # pool minus the watermark, or than the block-table width — DESIGN §9);
    # state is FINISHED with no output, this flag tells the two apart
    rejected: bool = False
    prefill_pos: int = 0                         # chunked-prefill progress
    # prefix sharing (DESIGN §10): prompt tokens served from shared blocks
    # at admission — prefill starts at this offset and only the suffix is
    # charged to the chunk budget
    cached_prefix_len: int = 0
    output_tokens: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1                               # engine batch slot
    lane: int = -1                               # PD-fusion prefill lane (DESIGN §6)
    prefill_start_time: float = -1.0             # first prefill chunk (TTFT attribution)
    first_token_time: float = -1.0
    finish_time: float = -1.0
    tbt_samples: List[float] = dataclasses.field(default_factory=list)
    # two-tier swap (DESIGN §11): per-request swap latency accounting
    swap_out_time: float = -1.0                  # pending swap-out timestamp
    swapped_s: float = 0.0                       # total time spent offloaded
    n_swaps: int = 0                             # completed swap round trips

    def __post_init__(self):
        if self.prompt_tokens is not None and self.prompt_len == 0:
            self.prompt_len = len(self.prompt_tokens)

    @property
    def output_len(self) -> int:
        return len(self.output_tokens) if self.output_tokens else self._sim_outlen

    _sim_outlen: int = 0

    @property
    def context_len(self) -> int:
        return self.prompt_len + max(len(self.output_tokens), self._sim_outlen)

    def sim_emit_token(self):
        self._sim_outlen += 1

    def sim_reset_output(self):
        """Recompute preemption (simulator): the engine regenerates the
        victim's output from scratch on re-admission, so the sim twin
        drops the emitted count to mirror it step-for-step (DESIGN §11)."""
        self._sim_outlen = 0

    @property
    def done(self) -> bool:
        n_out = max(len(self.output_tokens), self._sim_outlen)
        if self.true_output_len:
            return n_out >= min(self.true_output_len, self.max_new_tokens)
        return n_out >= self.max_new_tokens
