"""Continuous-batching serving engine over a real JAX model.

Runs the same controller stack as the simulator (Telemetry -> Policy ->
BlockManager, DESIGN §1) with actual jit-compiled prefill/decode steps and
wall-clock TBT feedback. Batch sizes are bucketized (TPU/XLA static shapes —
DESIGN §3): the decode step runs on the smallest compiled bucket >= active
requests, with inactive rows masked via position -1.

PD fusion (DESIGN §6) runs `n_prefill_lanes` spare physical cache rows past
the decode buckets; each scheduling interval the controller's chunk budget
is packed across occupied lanes and same-size lane chunks are batched into
one jit'd multi-row prefill graph. Finished lanes promote into the compacted
decode region.

Intended for reduced-config models on CPU (tests, Fig-3-style curves) and as
the production template for TPU serving (launch/serve.py).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig, ServeConfig
from repro.core.batching import bucketize, make_policy
from repro.core.lanes import lane_order, pack_chunks
from repro.core.memory_model import MemoryModel, kv_shard_factor
from repro.core.telemetry import Telemetry
from repro.models.model import Model
from repro.serving.cost_model import CostModel, PROFILES
from repro.serving.kv_cache import (BlockManager, prefix_cache_supported,
                                    swap_supported)
from repro.serving.request import Request, RequestState
from repro.serving.sampling import sample


def _batch_axis(name: str) -> int:
    return 0 if name == "pos" else 1


def cache_take(cache: Dict[str, Any], start: int, n: int) -> Dict[str, Any]:
    return {k: jax.lax.slice_in_dim(v, start, start + n, axis=_batch_axis(k))
            for k, v in cache.items()}


def cache_put(cache: Dict[str, Any], sub: Dict[str, Any],
              start: int) -> Dict[str, Any]:
    return {k: jax.lax.dynamic_update_slice_in_dim(
        v, sub[k], start, axis=_batch_axis(k)) for k, v in cache.items()}


def cache_copy_row(cache: Dict[str, Any], dst: int, src: int) -> Dict[str, Any]:
    out = {}
    for k, v in cache.items():
        ax = _batch_axis(k)
        row = jax.lax.index_in_dim(v, src, axis=ax, keepdims=False)
        idx = [slice(None)] * v.ndim
        idx[ax] = dst
        out[k] = v.at[tuple(idx)].set(row)
    return out


def state_clear_row(cache: Dict[str, Any], i: int) -> Dict[str, Any]:
    """Zero the per-slot state of one physical row — all paged mode needs
    (`pos` lives in the block pool there and is cleared when blocks free,
    DESIGN §9)."""
    out = dict(cache)
    for k in ("conv", "rec", "ssm"):
        if k in cache:
            out[k] = cache[k].at[:, i].set(0)
    return out


def cache_clear_row(cache: Dict[str, Any], i: int) -> Dict[str, Any]:
    out = state_clear_row(cache, i)
    if "pos" in cache:
        out["pos"] = cache["pos"].at[i].set(-1)
    return out


# per-slot state keys in paged mode: everything except the k/v/pos pools
_POOL_KEYS = ("k", "v", "pos")


@dataclasses.dataclass
class _StepRec:
    """One dispatched interval's retirement record (DESIGN §14).

    Dispatch runs every value-independent decision — admission, lane
    packing, grow/finish/preempt bookkeeping, block-table edits — and
    parks the value-DEPENDENT residue here: the device futures to fence
    on, the output-token placeholders to patch, and the telemetry feeds
    that must not land before the step's results exist."""
    #: device futures: "dec" sampled-token vector, "first" argmax scalars
    #: (promotions / non-chunked prefills), "probe" the last dispatched
    #: logits (fence anchor for prefill-only intervals)
    payload: Dict[str, Any] = dataclasses.field(default_factory=dict)
    #: (request, output index, life generation, "d"|"f", payload row)
    patches: List[Tuple[Request, int, int, str, int]] = \
        dataclasses.field(default_factory=list)
    #: (request, feed on_first_token, queue_s, prefill_start) TTFT stamps
    firsts: List[Tuple[Request, bool, float, float]] = \
        dataclasses.field(default_factory=list)
    #: (request, output length) completion stamps, finish order preserved
    completions: List[Tuple[Request, int]] = \
        dataclasses.field(default_factory=list)
    #: lane -> packed chunk tokens: the on_prefill_interval feed
    lane_tokens: Optional[Dict[int, int]] = None
    n_decode: int = 0
    dispatched: bool = False


def cache_gather(cache: Dict[str, Any], rows) -> Dict[str, Any]:
    """Gather a (possibly non-contiguous) set of physical rows into a
    compact sub-cache — the multi-lane prefill batch (DESIGN §6) and the
    paged per-slot state (DESIGN §9). Out-of-bounds rows (the paged
    padding sentinel) read as zeros — NOT the jnp.take default NaN fill,
    which would trip JAX_DEBUG_NANS on every padded step."""
    return {k: jnp.take(v, rows, axis=_batch_axis(k), mode="fill",
                        fill_value=0)
            for k, v in cache.items()}


def cache_scatter(cache: Dict[str, Any], sub: Dict[str, Any],
                  rows) -> Dict[str, Any]:
    """Scatter a gathered sub-cache back into its physical rows."""
    out = {}
    for k, v in cache.items():
        if _batch_axis(k) == 0:
            out[k] = v.at[rows].set(sub[k])
        else:
            out[k] = v.at[:, rows].set(sub[k])
    return out


class Engine:
    def __init__(self, model: Model, params, serve: ServeConfig,
                 max_context: int = 256,
                 buckets: Tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64),
                 prefill_chunk: int = 32, enc_len: int = 0, seed: int = 0,
                 temperature: float = 0.0,
                 cost: Optional[CostModel] = None, mesh=None):
        self.model = model
        self.cfg: ModelConfig = model.cfg
        self.serve = serve
        self.max_context = max_context
        self.buckets = tuple(sorted(b for b in buckets if b <= serve.b_max)) \
            or (serve.b_max,)
        self.max_slots = max(self.buckets)
        self.prefill_chunk = prefill_chunk
        self.params = params
        self.enc_len = enc_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)

        # mesh-sharded serving (DESIGN §12): params tensor-parallel over
        # "model" (§5 name rules, data axes replicated), the KV pool
        # sharded over "model" on kv-heads — per-chip pool quantities
        # scale by the effective shard count
        if mesh is None and serve.mesh_shape:
            from repro.launch.mesh import make_serving_mesh
            mesh = make_serving_mesh(serve.mesh_shape)
        self.mesh = mesh
        self.model_shards = 1
        if mesh is not None and "model" in mesh.axis_names:
            self.model_shards = kv_shard_factor(self.cfg,
                                                int(mesh.shape["model"]))

        # n_prefill_lanes spare physical rows: PD-fusion prefilling requests
        # live outside every decode bucket so masked decode steps can never
        # touch their (stateful) cache rows (DESIGN §6)
        self.n_lanes = max(1, serve.n_prefill_lanes)
        eta = serve.kv_pool_tokens or self.max_slots * max_context
        # per-chip scaling (DESIGN §12) applies to EXPLICIT budgets only:
        # the slot-derived fallback is already the maximum the block
        # tables can address, so scaling it by the shard count would
        # allocate pool blocks no table could ever reference
        pool_shards = self.model_shards if serve.kv_pool_tokens else 1
        self.mem = MemoryModel(self.cfg, hbm_budget_bytes=0,
                               eps_m=serve.eps_m,
                               block_size=serve.block_size, eta_tokens=eta,
                               model_shards=pool_shards)
        self.paged = serve.paged_kv
        # ref-counted prefix sharing (DESIGN §10): needs the paged pool (the
        # contiguous layout has no shareable physical blocks) and a family
        # whose prefix lives entirely in attention K/V blocks
        self.prefix = (serve.prefix_cache and self.paged
                       and prefix_cache_supported(self.cfg)
                       and self.mem.bytes_per_token != 0)
        # two-tier swap space (DESIGN §11): needs the paged pool (swap moves
        # physical blocks) and a family whose per-request state lives
        # entirely in the K/V block pools
        self.swap = (serve.swap_space_blocks > 0
                     and serve.preempt != "recompute" and self.paged
                     and swap_supported(self.cfg)
                     and self.mem.bytes_per_token != 0)
        self.blocks = BlockManager(self.mem.eta, serve.block_size,
                                   prefix_cache=self.prefix,
                                   swap_space_blocks=serve.swap_space_blocks
                                   if self.swap else 0)
        # swap-vs-recompute crossover (DESIGN §11): the same CostModel the
        # simulator twin uses; only the PCIe/prefill time laws are read
        self.cost = cost or CostModel(self.cfg, PROFILES["a100x8"])
        self.n_slots = self.max_slots + self.n_lanes
        # per-request block-table width: enough blocks for a full context
        self.max_blocks = -(-max_context // serve.block_size)
        if self.paged:
            # physically paged cache (DESIGN §9): K/V pools sized by the
            # allocator's block count — BlockManager's tables ARE the
            # storage map. Requests pin a per-slot state row for life.
            cache_fn = lambda: model.init_paged_cache(  # noqa: E731
                self.n_slots, self.mem.num_blocks, serve.block_size,
                enc_len=enc_len)
            self._free_slots = list(range(self.n_slots))
        else:
            cache_fn = lambda: model.init_cache(  # noqa: E731
                self.n_slots, max_context, enc_len=enc_len,
                prefill_chunk=prefill_chunk)
        self.cache = self._init_cache_on_mesh(cache_fn)
        if self.mesh is not None:
            self._shard_state()
        self.tel = Telemetry()
        self.policy = make_policy(serve, self.mem)

        self.waiting: List[Request] = []
        self.active: List[Request] = []          # compact: slot i = active[i]
        # PD fusion (DESIGN §6): admitted requests being chunk-prefilled.
        # A request with r.lane >= 0 owns physical row max_slots + r.lane;
        # the rest queue for a free lane.
        self.prefilling: List[Request] = []
        self.lanes: List[Optional[Request]] = [None] * self.n_lanes
        # two-tier swap (DESIGN §11): offloaded requests awaiting swap-in;
        # admission drains this queue before `waiting`
        self.swapped: List[Request] = []
        self.now0 = time.perf_counter()
        self._next_rid = 0
        self.total_decoded = 0
        self.total_finished = 0
        self.admitted_total = 0   # successful admissions from `waiting`
        self.preemptions = 0      # evictions, recompute + swap-out alike
        self.oom_events = 0       # admission refusals at the watermark
        self.rejected = 0         # requests too large for the pool, dropped
        # per-request goodput SLOs (DESIGN §15): verdicts stamp at
        # retirement (timestamps are final there); rejected requests
        # count against attainment
        self.sla_requests_met = 0
        self.goodput_tokens = 0
        self.swap_outs = 0        # victims offloaded to the host pool
        self.swap_ins = 0         # offloaded requests restored
        self.swap_out_bytes = 0
        self.swap_in_bytes = 0
        self.swap_wait_trace: List[float] = []   # per-round-trip latency (s)
        # host-side swap storage: one numpy row set per host block, shaped
        # like the device pools (k/v block axis 1, pos axis 0)
        self._host_pool: Dict[str, np.ndarray] = {}
        if self.swap:
            nhb = serve.swap_space_blocks
            for k in _POOL_KEYS:
                v = self.cache.get(k)
                if v is None:
                    continue
                shape = ((v.shape[0], nhb) + v.shape[2:]) if k != "pos" \
                    else (nhb,) + v.shape[1:]
                self._host_pool[k] = np.zeros(shape, v.dtype)
        # contiguous-layout row copies (promotion/compaction/eviction);
        # stays 0 under paged_kv — the paged layout's headline win
        self.copy_rows = 0
        self.copy_bytes = 0
        self._row_bytes = 0 if self.paged else sum(
            int(v.size // v.shape[_batch_axis(k)]) * v.dtype.itemsize
            for k, v in self.cache.items())
        # per-block pool bytes: the unit a COW duplication copies (DESIGN §10)
        self._blk_bytes = sum(
            int(v.size // v.shape[0 if k == "pos" else 1]) * v.dtype.itemsize
            for k, v in self.cache.items() if k in _POOL_KEYS) \
            if self.paged else 0
        self.decode_steps = 0
        self.batch_trace: List[int] = []
        self.tbt_trace: List[float] = []
        # per-request TTFT (queue wait + prefill service), for the p90/mean
        # twins of SimResult (DESIGN §7 differential harness)
        self.ttft_trace: List[float] = []
        # SLA attainment, sim-mirrored: decode steps within d_sla + eps_d
        self._sla_ok = 0
        self._sla_steps = 0
        # per-interval packed prefill tokens (packer audit: sum of lane
        # chunks each fused interval; each entry <= that interval's budget)
        self.prefill_tokens_trace: List[int] = []
        # async dispatch-ahead pipeline (DESIGN §14): up to overlap_depth
        # dispatched intervals stay un-fenced while the host schedules the
        # next one against the live allocator + stale telemetry feeds;
        # 0 = the synchronous loop (each interval retires in its own call)
        self.overlap_depth = max(0, int(serve.overlap_depth))
        self._inflight: "collections.deque[_StepRec]" = collections.deque()
        # rid -> device scalar of the request's newest not-yet-retired
        # token: the next decode step's input, spliced in without readback
        self._pending_tok: Dict[int, Any] = {}
        # rid -> life generation, bumped by _evict: retirement drops
        # patches recorded against an earlier (cleared) life
        self._gen: Dict[int, int] = {}
        # host-vs-device interval split (DESIGN §14): per step() call,
        # device_s = the retirement fence wait, host_s = the remainder
        self.step_host_trace: List[float] = []
        self.step_device_trace: List[float] = []

        self._decode_jit = self._mesh_call(jax.jit(self._decode_fn))
        self._prefill_jit = self._mesh_call(jax.jit(self._prefill_fn))
        self._prefill_lanes_jit = self._mesh_call(
            jax.jit(self._prefill_lanes_fn))
        # donate the cache operand (arg 5 in both paged fns) so XLA updates
        # the K/V pools in place instead of copying them every step — the
        # whole point of the paged layout. CPU doesn't implement donation
        # (it would just warn), so only donate on accelerators.
        donate = () if jax.default_backend() == "cpu" else (5,)
        self._decode_paged_jit = self._mesh_call(
            jax.jit(self._decode_paged_fn, donate_argnums=donate))
        self._prefill_paged_jit = self._mesh_call(
            jax.jit(self._prefill_paged_fn, donate_argnums=donate))
        # device-table cache keyed by (call-site, shape): fused intervals
        # alternate between the prefill-group and decode-bucket tables
        # (which can share a shape), so a single slot would thrash
        self._tables_dev: Dict[Tuple[str, Tuple[int, int]],
                               Tuple[np.ndarray, jnp.ndarray]] = {}

    # -- mesh-sharded serving (DESIGN §12) -------------------------------------
    def _init_cache_on_mesh(self, cache_fn):
        """Allocate the serving cache — directly under its mesh shardings
        when a mesh is set. The paged pool is `model_shards`× the per-chip
        budget, so materializing it on one device first (then resharding)
        would OOM exactly the chips §12 is sized for; jit with
        out_shardings creates each shard in place."""
        if self.mesh is None:
            return cache_fn()
        from repro.distributed.sharding import serve_cache_shardings
        shardings = serve_cache_shardings(
            jax.eval_shape(cache_fn), self.cfg, self.mesh)
        with self.mesh:
            return jax.jit(cache_fn, out_shardings=shardings)()

    def _shard_state(self):
        """Place params on the mesh: TP over "model" (§5 rules, data axes
        replicated). Params arrive caller-materialized, so this is a
        reshard (`device_put`); production callers serving models that
        don't fit one chip should init params under
        `serve_param_shardings` to begin with (the cache never needs this
        — `_init_cache_on_mesh` allocates it sharded)."""
        from repro.distributed.sharding import serve_param_shardings
        self.params = jax.device_put(
            self.params,
            serve_param_shardings(self.params, self.cfg, self.mesh))

    def _mesh_call(self, jf):
        """Wrap a jit'd step so it runs inside the mesh context with the
        ambient serving mesh installed (routes the paged kernel through
        its shard_map wrapper at trace time — DESIGN §12). No-op without
        a mesh: the single-device engine is byte-for-byte untouched."""
        if self.mesh is None:
            return jf

        from repro.distributed import sharding as _sharding

        def call(*args):
            prev = _sharding.set_serving_mesh(self.mesh)
            try:
                with self.mesh:
                    return jf(*args)
            finally:
                _sharding.set_serving_mesh(prev)

        return call

    # -- jit'd steps ----------------------------------------------------------
    def _decode_fn(self, params, tokens, seq_lens, cache):
        return self.model.decode_step(params, tokens, seq_lens, cache)

    def _prefill_fn(self, params, tokens, positions, cache, extras):
        return self.model.prefill(params, tokens, positions, cache, extras)

    def _prefill_lanes_fn(self, params, tokens, positions, cache, rows):
        """Multi-row lane prefill: gather the lane rows into one batch, run
        a single prefill graph, scatter the rows back (DESIGN §6). Compiles
        one graph per (n_rows, chunk_len) shape."""
        sub = cache_gather(cache, rows)
        logits, sub = self.model.prefill(params, tokens, positions, sub, None)
        return logits, cache_scatter(cache, sub, rows)

    # -- paged-mode jit'd steps (DESIGN §9) ------------------------------------
    # K/V pools + the pos map are global (no batch axis); per-slot state is
    # gathered by the requests' pinned rows, run, and scattered back. Row
    # index n_slots is the padding sentinel: its gathers read as zeros
    # (cache_gather fills OOB) and its scatters drop.
    def _split_state(self, cache):
        return {k: v for k, v in cache.items() if k not in _POOL_KEYS}

    def _merge_paged(self, cache, sub, rows):
        out = dict(cache)
        for k in _POOL_KEYS:
            if k in sub:
                out[k] = sub[k]
        state_new = self._split_state(sub)
        if state_new:
            out.update(cache_scatter(
                {k: cache[k] for k in state_new}, state_new, rows))
        return out

    def _decode_paged_fn(self, params, tokens, seq_lens, tables, rows, cache):
        sub = cache_gather(self._split_state(cache), rows)
        for k in _POOL_KEYS:
            if k in cache:
                sub[k] = cache[k]
        logits, sub = self.model.decode_step_paged(
            params, tokens, seq_lens, tables, sub)
        return logits, self._merge_paged(cache, sub, rows)

    def _prefill_paged_fn(self, params, tokens, positions, tables, rows,
                          cache, extras):
        sub = cache_gather(self._split_state(cache), rows)
        for k in _POOL_KEYS:
            if k in cache:
                sub[k] = cache[k]
        logits, sub = self.model.prefill_paged(
            params, tokens, positions, tables, sub, extras)
        return logits, self._merge_paged(cache, sub, rows)

    # -- paged-mode host-side helpers ------------------------------------------
    def _tables_for(self, reqs, pad_to: int = 0,
                    kind: str = "prefill") -> jnp.ndarray:
        """Device block tables for a batch: row i holds request i's physical
        block ids from the BlockManager, -1-padded (DESIGN §9). Tables only
        change on block grow / membership changes (at most once per
        block_size steps per request), so the device upload is reused while
        the host copy is unchanged."""
        n = max(pad_to, len(reqs), 1)
        tbl = np.full((n, self.max_blocks), -1, np.int32)
        for i, r in enumerate(reqs):
            ids = self.blocks.tables.get(r.rid, [])
            tbl[i, :len(ids)] = ids
        key = (kind, tbl.shape)
        cached = self._tables_dev.get(key)
        if cached is not None and np.array_equal(cached[0], tbl):
            return cached[1]
        dev = jnp.asarray(tbl)
        self._tables_dev[key] = (tbl, dev)
        return dev

    def _release_blocks(self, freed: List[int]):
        """Clear the pos-pool rows of freed blocks so a future tenant never
        sees the previous request's stale positions (DESIGN §9)."""
        if self.paged and freed and "pos" in self.cache:
            out = dict(self.cache)
            out["pos"] = out["pos"].at[jnp.asarray(freed, jnp.int32)].set(-1)
            self.cache = out

    def _drain_released(self):
        """Clear pos rows of blocks the allocator evicted from the prefix
        cache for reuse (DESIGN §10): a new tenant must never see the cached
        tenant's stale positions."""
        self._release_blocks(self.blocks.take_released())

    def _cow_blocks(self, pairs):
        """Apply copy-on-write block duplications the allocator ordered
        (`BlockManager.cow_range`): copy the K/V/pos pool rows from the
        shared source block into the private copy. Suffix-aligned mapping
        keeps this off the steady-state path (DESIGN §10)."""
        if not pairs:
            return
        out = dict(self.cache)
        for src, dst in pairs:
            for k in ("k", "v"):
                if k in out:
                    out[k] = out[k].at[:, dst].set(out[k][:, src])
            if "pos" in out:
                out["pos"] = out["pos"].at[dst].set(out["pos"][src])
            self.copy_bytes += self._blk_bytes
        self.cache = out

    def _free_request(self, r) -> None:
        """Release a request's blocks (+ slot/pos rows in paged mode).
        Under prefix sharing this is a decref: registered blocks stay
        resident as evictable cache and keep their pos rows (DESIGN §10)."""
        freed = self.blocks.free(r.rid)
        # the request's newest token no longer feeds a next decode step;
        # pending patches read the retirement record's payload directly
        self._pending_tok.pop(r.rid, None)
        if self.paged:
            self._release_blocks(freed)
            if r.slot >= 0:
                self._free_slots.append(r.slot)
                r.slot = -1

    def _copy_row(self, dst: int, src: int):
        self.cache = cache_copy_row(self.cache, dst, src)
        self.copy_rows += 1
        self.copy_bytes += self._row_bytes

    # -- public API -------------------------------------------------------------
    def submit(self, prompt_tokens: List[int], max_new_tokens: int = 0,
               extras: Optional[Dict[str, jnp.ndarray]] = None,
               arrival_time: Optional[float] = None) -> Request:
        t = arrival_time if arrival_time is not None else self._now()
        mx = max_new_tokens or self.serve.max_new_tokens
        mx = min(mx, self.max_context - len(prompt_tokens) - 1)
        r = Request(rid=self._next_rid, arrival_time=t,
                    prompt_tokens=list(prompt_tokens), max_new_tokens=mx)
        self._next_rid += 1
        r.extras = extras
        self.waiting.append(r)
        self.tel.on_arrival(t, r.prompt_len)
        return r

    def warmup(self):
        """Compile decode buckets + prefill graphs so TBT feedback is clean.

        Covers every full-chunk shape: the single-row graph plus one
        multi-row lane graph per group size 2..n_prefill_lanes (tail chunks
        still compile on first use — one graph per distinct tail length)."""
        if self.paged:
            # all-padding warmup batches: positions -1 write nothing, table
            # entries -1 read nothing, sentinel rows scatter-drop. The cache
            # operand is donated, so rebind the returned (content-identical)
            # cache each call.
            for b in self.buckets:
                toks = jnp.zeros((b,), jnp.int32)
                lens = jnp.full((b,), -1, jnp.int32)
                tables = jnp.full((b, self.max_blocks), -1, jnp.int32)
                rows = jnp.full((b,), self.n_slots, jnp.int32)
                logits, self.cache = self._decode_paged_jit(
                    self.params, toks, lens, tables, rows, self.cache)
                jax.block_until_ready(logits)
            for g in range(1, self.n_lanes + 1):
                tt = jnp.zeros((g, self.prefill_chunk), jnp.int32)
                pos = jnp.full((g, self.prefill_chunk), -1, jnp.int32)
                tables = jnp.full((g, self.max_blocks), -1, jnp.int32)
                rows = jnp.full((g,), self.n_slots, jnp.int32)
                logits, self.cache = self._prefill_paged_jit(
                    self.params, tt, pos, tables, rows, self.cache, None)
                jax.block_until_ready(logits)
            return
        for b in self.buckets:
            sub = cache_take(self.cache, 0, b)
            toks = jnp.zeros((b,), jnp.int32)
            lens = jnp.full((b,), -1, jnp.int32)
            jax.block_until_ready(self._decode_jit(self.params, toks, lens, sub))
        sub = cache_take(self.cache, 0, 1)
        tt = jnp.zeros((1, self.prefill_chunk), jnp.int32)
        pos = jnp.full((1, self.prefill_chunk), -1, jnp.int32)
        jax.block_until_ready(
            self._prefill_jit(self.params, tt, pos, sub, None))
        for g in range(2, self.n_lanes + 1):
            rows = jnp.arange(self.max_slots, self.max_slots + g, dtype=jnp.int32)
            tt = jnp.zeros((g, self.prefill_chunk), jnp.int32)
            pos = jnp.full((g, self.prefill_chunk), -1, jnp.int32)
            logits, _ = self._prefill_lanes_jit(self.params, tt, pos,
                                                self.cache, rows)
            jax.block_until_ready(logits)

    def _now(self) -> float:
        return time.perf_counter() - self.now0

    # -- scheduling interval -------------------------------------------------------
    def step(self) -> bool:
        """One scheduling interval. Returns False when fully idle.

        Async dispatch-ahead pipeline (DESIGN §14): schedule interval N
        against telemetry whose TBT/TTFT/throughput feeds are stale by up
        to `overlap_depth` un-retired intervals (Alg 1 tolerates stale
        snapshots — pool occupancy is always read live from the
        allocator), dispatch N's prefill/decode graphs WITHOUT fencing,
        then retire the oldest in-flight interval(s) until at most
        `overlap_depth` device steps remain in flight. Depth 0 retires N
        before returning — the synchronous loop, interval for interval.
        """
        if not self.waiting and not self.active and not self.prefilling \
                and not self.swapped:
            # pipeline drain: retirement only patches token values,
            # timestamps and telemetry — it never creates schedulable
            # work — so the drained call still reports idle and run()'s
            # step count matches the synchronous loop exactly
            while self._inflight:
                self._retire_step()
            return False
        t0 = time.perf_counter()
        tel = self.tel.snapshot(
            now=self._now(),
            n_prefill=len(self.waiting) + len(self.prefilling),
            n_decode=len(self.active), free_tokens=self.blocks.free_tokens,
            logical_used_tokens=self.blocks.logical_used_tokens,
            physical_used_tokens=self.blocks.physical_used_tokens,
            swapped_tokens=self.blocks.swapped_tokens)
        decision = self.policy.step(tel)
        # sim-mirrored admission (DESIGN §7): bucketize the controller's cap
        # to the compiled batch buckets and apply the shared
        # BlockManager.admission_verdict (vLLM 1% watermark + unservable
        # rejection), counting watermark refusals as oom_events.
        # bucketize rounds UP to the floor bucket when b_t is below the
        # smallest compiled one — admitted rows must still respect the
        # controller's decision (the graph pads, admission must not)
        cap = bucketize(decision.max_batch, self.serve.batch_buckets) \
            if self.serve.batch_buckets else decision.max_batch
        cap = min(cap, decision.max_batch, self.max_slots)
        rec = _StepRec()

        # swap-in drain (DESIGN §11): offloaded requests re-enter BEFORE
        # any new admission — they resume decode without re-prefill, and
        # while any remain, `waiting` is held back so fresh arrivals can
        # never starve the swap-in path of pool headroom
        while self.swapped \
                and len(self.active) + len(self.prefilling) < cap:
            if not self._swap_in_next():
                self.oom_events += 1
                break

        # admission
        while self.waiting and not self.swapped \
                and len(self.active) + len(self.prefilling) < cap:
            r = self.waiting[0]
            need = r.prompt_len + 1
            if self.mem.bytes_per_token == 0:
                need = self.serve.block_size
            # prefix sharing (DESIGN §10): map every indexed full prompt
            # block into the table first (zero copies), then gate admission
            # on the unmatched suffix only — rolled back on refusal
            cached = 0
            if self.prefix and r.prompt_tokens:
                cached = self.blocks.acquire_prefix(r.rid, r.prompt_tokens)
            have = len(self.blocks.tables.get(r.rid, ()))
            nb = self.blocks.blocks_needed(0, need, r.rid)
            mb = self.max_blocks - have
            verdict = "reject" if mb <= 0 and nb > 0 \
                else self.blocks.admission_verdict(nb, mb)
            if verdict != "admit":
                if cached:
                    self.blocks.free(r.rid)
                if verdict == "reject":
                    # no pool state can ever hold it (bigger than the pool
                    # minus the watermark, or than the block-table width):
                    # drop it rather than wedging the queue behind it
                    self.waiting.pop(0)
                    r.state = RequestState.FINISHED
                    r.rejected = True
                    r.finish_time = self._now()
                    # goodput verdict (DESIGN §15): a dropped request
                    # counts against attainment, never for it
                    r.stamp_sla(self.serve.ttft_sla_s, self.serve.tbt_sla_ms)
                    self.rejected += 1
                    continue
                self.oom_events += 1
                break
            self.blocks.allocate(r.rid, 0, need)
            if self.prefix:
                self.blocks.note_prefix_query(r.prompt_len, cached)
            r.cached_prefix_len = cached
            self.waiting.pop(0)
            self.admitted_total += 1
            if self.serve.chunked_prefill:
                r.state = RequestState.PREFILLING
                r.prefill_pos = cached
                self.prefilling.append(r)
            else:
                self._prefill_request(r, rec)
        self._drain_released()

        self._preempt_if_needed()
        if self.serve.chunked_prefill:
            # PD fusion: one fused interval = a prefill chunk (within the
            # controller's token budget) + the decode batch; TBT accounts
            # for both (the paper's adaptive-chunk-size scenario)
            budget = decision.chunk_budget \
                or self.serve.chunk_budget_tokens
            if budget <= 0 and self.prefilling and not self.active:
                # nothing decoding and no token budget: the engine would
                # spin no-op intervals forever — make minimum progress on
                # one full chunk instead of livelocking
                budget = self.prefill_chunk
            self._advance_prefill(budget, rec)
            if self.active:
                self._decode_once(rec)
        elif self.active:
            self._decode_once(rec)
        if rec.dispatched:
            self._inflight.append(rec)
        # retire down to the pipeline depth: the fence wait is the
        # interval's device time; everything else this call did is host
        # work the in-flight step(s) just hid
        device_s = 0.0
        while len(self._inflight) > self.overlap_depth:
            device_s += self._retire_step()
        host_s = (time.perf_counter() - t0) - device_s
        self.step_host_trace.append(host_s)
        self.step_device_trace.append(device_s)
        # fed live, not lagged: the split is produced by retirement
        # itself, not by the interval being scheduled (DESIGN §14)
        self.tel.on_interval(host_s, device_s)
        return True

    # -- PD fusion internals (DESIGN §6) ---------------------------------------
    def _fill_lanes(self):
        """Assign queued prefilling requests to free lanes (sticky: a lane
        keeps its request until promotion)."""
        queued = [(None, r) for r in self.prefilling if r.lane < 0]
        if not queued:
            return
        queued = lane_order(self.serve.prefill_pack, queued)
        for j in range(self.n_lanes):
            if self.lanes[j] is not None:
                continue
            if not queued:
                break
            _, r = queued.pop(0)
            if self.paged:
                # pin a state row for the request's whole life: promotion
                # will be a pure bookkeeping move (DESIGN §9)
                slot = self._free_slots.pop()
                self.cache = state_clear_row(self.cache, slot)
            else:
                slot = self.max_slots + j
                self.cache = cache_clear_row(self.cache, slot)
            r.lane = j
            r.slot = slot
            self.lanes[j] = r

    def _advance_prefill(self, budget_tokens: int, rec: _StepRec) -> None:
        """Advance up to n_prefill_lanes prefilling requests by one chunk
        each, within the interval's token budget (shared packer:
        core.lanes.pack_chunks). Dispatch-only (DESIGN §14): no fence —
        the chunk logits land in `rec` and promoted first tokens are
        patched at retirement."""
        if not self.prefilling or budget_tokens <= 0:
            return
        self._fill_lanes()
        plan = pack_chunks(self.serve.prefill_pack, self.lanes,
                           budget_tokens, self.prefill_chunk)
        if not plan:
            return
        for _, r, _ in plan:
            if r.prefill_start_time < 0:
                r.prefill_start_time = self._now()
        if self.prefix:
            # COW guard (DESIGN §10): a shared block in this chunk's write
            # range gets a private copy first — structurally unreachable
            # with block-aligned suffixes, kept as the safety invariant
            for _, r, take in plan:
                self._cow_blocks(self.blocks.cow_range(
                    r.rid, r.prefill_pos, r.prefill_pos + take))

        # batch same-size chunks into one multi-row graph; first chunks
        # carrying extras (image/audio embeddings differ per request) run
        # as single-row calls on the existing contiguous path
        single = [(j, r, t) for j, r, t in plan
                  if r.prefill_pos == 0 and getattr(r, "extras", None)
                  is not None]
        single_lanes = {j for j, _, _ in single}
        groups: Dict[int, list] = {}
        for j, r, t in plan:
            if j in single_lanes:
                continue
            groups.setdefault(t, []).append((j, r, t))

        last_logits: Dict[int, Any] = {}   # lane -> logits of its chunk
        for j, r, take in single:
            piece = r.prompt_tokens[:take]
            tt = jnp.array([piece], jnp.int32)
            pos = jnp.array([list(range(take))], jnp.int32)
            if self.paged:
                logits, self.cache = self._prefill_paged_jit(
                    self.params, tt, pos, self._tables_for([r]),
                    jnp.array([r.slot], jnp.int32), self.cache, r.extras)
            else:
                slot = self.max_slots + j
                sub = cache_take(self.cache, slot, 1)
                logits, sub = self._prefill_jit(self.params, tt, pos, sub,
                                                r.extras)
                self.cache = cache_put(self.cache, sub, slot)
            rec.dispatched = True
            rec.payload["probe"] = logits
            last_logits[j] = logits[0]
        for take, entries in groups.items():
            if self.paged:
                # one paged graph per (rows, chunk) shape: the requests'
                # pinned state rows + block tables (DESIGN §9)
                reqs = [r for _, r, _ in entries]
                rows = jnp.array([r.slot for r in reqs], jnp.int32)
                tt = jnp.array(
                    [r.prompt_tokens[r.prefill_pos:r.prefill_pos + take]
                     for r in reqs], jnp.int32)
                pos = jnp.array(
                    [list(range(r.prefill_pos, r.prefill_pos + take))
                     for r in reqs], jnp.int32)
                logits, self.cache = self._prefill_paged_jit(
                    self.params, tt, pos, self._tables_for(reqs), rows,
                    self.cache, None)
                rec.dispatched = True
                rec.payload["probe"] = logits
                for i, (j, _, _) in enumerate(entries):
                    last_logits[j] = logits[i]
                continue
            if len(entries) == 1:
                # single row: contiguous slice path (identical graph to the
                # legacy single-spare-row engine — keeps n_prefill_lanes=1
                # bit-for-bit)
                j, r, _ = entries[0]
                slot = self.max_slots + j
                piece = r.prompt_tokens[r.prefill_pos:r.prefill_pos + take]
                tt = jnp.array([piece], jnp.int32)
                pos = jnp.array([list(range(r.prefill_pos,
                                            r.prefill_pos + take))], jnp.int32)
                sub = cache_take(self.cache, slot, 1)
                logits, sub = self._prefill_jit(self.params, tt, pos, sub,
                                                None)
                self.cache = cache_put(self.cache, sub, slot)
                rec.dispatched = True
                rec.payload["probe"] = logits
                last_logits[j] = logits[0]
                continue
            rows = jnp.array([self.max_slots + j for j, _, _ in entries],
                             jnp.int32)
            tt = jnp.array(
                [r.prompt_tokens[r.prefill_pos:r.prefill_pos + take]
                 for _, r, _ in entries], jnp.int32)
            pos = jnp.array(
                [list(range(r.prefill_pos, r.prefill_pos + take))
                 for _, r, _ in entries], jnp.int32)
            logits, self.cache = self._prefill_lanes_jit(
                self.params, tt, pos, self.cache, rows)
            rec.dispatched = True
            rec.payload["probe"] = logits
            for i, (j, _, _) in enumerate(entries):
                last_logits[j] = logits[i]

        # deferred feed (DESIGN §14): lands when this interval retires
        rec.lane_tokens = {j: t for j, _, t in plan}
        self.prefill_tokens_trace.append(sum(t for _, _, t in plan))
        for _, r, take in plan:
            r.prefill_pos += take
            if self.prefix:
                # the chunk's K/V is in the pool: register its full blocks
                # in the prefix index (DESIGN §10)
                self.blocks.commit_prefill(r.rid, r.prompt_tokens,
                                           r.prefill_pos)
        # promote finished lanes (lane-index order: deterministic) into the
        # decode region: paged mode keeps the pinned row — an O(1)
        # bookkeeping move, zero tensor copies (DESIGN §9); contiguous mode
        # copies the lane row into the compacted region
        for j, r, take in sorted(plan, key=lambda e: e[0]):
            if r.prefill_pos < r.prompt_len:
                continue
            self.prefilling.remove(r)
            self.lanes[j] = None
            if not self.paged:
                dst = len(self.active)
                self._copy_row(dst, self.max_slots + j)
                r.slot = dst
            r.lane = -1
            r.state = RequestState.RUNNING
            # first token: the argmax stays on device; the TTFT stamp and
            # on_first_token feed land at retirement, when the token
            # actually exists (DESIGN §14) — queue_s is captured now so an
            # eviction between dispatch and retire can't corrupt the feed
            tok = jnp.argmax(last_logits[j][take - 1])
            flist = rec.payload.setdefault("first", [])
            rec.patches.append((r, len(r.output_tokens),
                                self._gen.get(r.rid, 0), "f", len(flist)))
            flist.append(tok)
            self._pending_tok[r.rid] = tok
            rec.firsts.append((r, True,
                               r.prefill_start_time - r.arrival_time,
                               r.prefill_start_time))
            r.output_tokens.append(None)
            self.active.append(r)

    def run(self, max_steps: int = 100_000) -> int:
        steps = 0
        while self.step() and steps < max_steps:
            steps += 1
        return steps

    # -- internals ---------------------------------------------------------------
    def _prefill_request(self, r: Request, rec: _StepRec):
        # admission may have evicted cached blocks into this request's
        # table: their stale pos rows must be cleared before the first
        # attention read over the table (DESIGN §10)
        self._drain_released()
        if self.paged:
            slot = self._free_slots.pop()
            r.slot = slot
            self.cache = state_clear_row(self.cache, slot)
        else:
            slot = len(self.active)
            r.slot = slot
            self.cache = cache_clear_row(self.cache, slot)
        r.state = RequestState.PREFILLING
        chunk = self.prefill_chunk
        toks = r.prompt_tokens
        extras = getattr(r, "extras", None)
        last_logits = None
        # exact-size chunks: stateful families (SSM conv/recurrence) must not
        # see pad tokens — full chunks + one exact-size tail call (jit caches
        # one graph per distinct tail length). A shared prefix (DESIGN §10)
        # is already resident in mapped blocks: prefill the suffix only.
        start0 = r.cached_prefix_len if self.prefix else 0
        pieces = [(s, toks[s:s + chunk]) for s in range(start0, len(toks), chunk)]
        if self.paged:
            if self.prefix:
                self._cow_blocks(self.blocks.cow_range(r.rid, start0,
                                                       len(toks)))
            tables = self._tables_for([r])
            rows = jnp.array([slot], jnp.int32)
            for start, piece in pieces:
                tt = jnp.array([piece], jnp.int32)
                pos = jnp.array([list(range(start, start + len(piece)))],
                                jnp.int32)
                ex = extras if start == 0 else None
                logits, self.cache = self._prefill_paged_jit(
                    self.params, tt, pos, tables, rows, self.cache, ex)
                last_logits = logits[0, len(piece) - 1]
            if self.prefix:
                self.blocks.commit_prefill(r.rid, toks, len(toks))
        else:
            sub = cache_take(self.cache, slot, 1)
            for start, piece in pieces:
                tt = jnp.array([piece], jnp.int32)
                pos = jnp.array([list(range(start, start + len(piece)))],
                                jnp.int32)
                ex = extras if start == 0 else None
                logits, sub = self._prefill_jit(self.params, tt, pos, sub, ex)
                last_logits = logits[0, len(piece) - 1]
            self.cache = cache_put(self.cache, sub, slot)
        r.state = RequestState.RUNNING
        # first token deferred to retirement (DESIGN §14); the synchronous
        # path never fed on_first_token here (no chunked service split),
        # so only the TTFT stamp rides in rec.firsts
        tok = jnp.argmax(last_logits)
        flist = rec.payload.setdefault("first", [])
        rec.patches.append((r, len(r.output_tokens),
                            self._gen.get(r.rid, 0), "f", len(flist)))
        flist.append(tok)
        self._pending_tok[r.rid] = tok
        rec.firsts.append((r, False, 0.0, 0.0))
        r.output_tokens.append(None)
        rec.dispatched = True
        rec.payload["probe"] = last_logits
        self.active.append(r)

    def _preempt_if_needed(self):
        if self.mem.bytes_per_token == 0:
            return  # constant per-request state: decode never grows it
        while self.active:
            need = sum(self.blocks.blocks_needed(r.context_len, 1, r.rid)
                       for r in self.active)
            if need <= self.blocks.free_blocks:
                return
            # newest victim first in BOTH modes (vLLM preemption order);
            # per victim, the DESIGN §11 crossover picks swap vs recompute
            victim = self.active[-1]
            if self._should_swap(victim):
                self._swap_out(len(self.active) - 1, victim)
            else:
                self._evict(len(self.active) - 1, victim)

    def _should_swap(self, r: Request) -> bool:
        """Per-victim preemption choice (DESIGN §11): swap only when the
        host pool can take the victim whole (shared ref>1 blocks are never
        swapped — the recompute path decrefs them instead) and the
        cost-model crossover says PCIe beats re-prefill. preempt="swap"
        forces swap whenever it is possible at all."""
        if not self.swap \
                or not self.blocks.can_swap_out(r.rid, self.max_blocks):
            return False
        if self.serve.preempt == "swap":
            return True
        return self.cost.swap_beats_recompute(
            len(self.blocks.tables[r.rid]), self.serve.block_size,
            r.context_len)

    def _swap_out(self, slot: int, r: Request):
        """Offload active[slot]'s KV blocks to the host pool: an O(blocks)
        `jax.device_get` of the victim's K/V/pos pool rows, then O(1)
        bookkeeping — its generated tokens and TTFT stand, it re-enters
        through the swapped queue without re-prefill (DESIGN §11)."""
        pairs = self.blocks.swap_out(r.rid)
        dev = jnp.asarray([d for d, _ in pairs], jnp.int32)
        host = np.array([h for _, h in pairs], np.int32)
        for k, hp in self._host_pool.items():
            ax = 0 if k == "pos" else 1
            rows = jax.device_get(jnp.take(self.cache[k], dev, axis=ax))
            if k == "pos":
                hp[host] = rows
            else:
                hp[:, host] = rows
        # the device blocks are free now: clear their pos rows so a new
        # tenant never sees the swapped-out tenant's stale positions
        self._release_blocks([int(d) for d, _ in pairs])
        # model-level KV payload bytes — the SAME accounting the sim twin
        # and CostModel.pcie_s use, so the differential harness can assert
        # byte parity (the physical rows moved may be wider: pos map +
        # fp32 test pools)
        self.swap_out_bytes += self.mem.blocks_to_bytes(len(pairs))
        self.swap_outs += 1
        self.preemptions += 1
        r.state = RequestState.SWAPPED
        r.swap_out_time = self._now()
        if r.slot >= 0:
            self._free_slots.append(r.slot)
            r.slot = -1
        self.active.pop(slot)
        self.swapped.append(r)

    def _swap_in_next(self) -> bool:
        """Restore the oldest swapped request (FIFO) onto fresh device
        blocks, gated by the same watermark verdict as admission. Returns
        False when the pool cannot take it yet."""
        r = self.swapped[0]
        nb = len(self.blocks.swapped_tables[r.rid])
        if self.blocks.admission_verdict(nb, self.max_blocks) != "admit":
            return False
        pairs = self.blocks.swap_in(r.rid)
        # stale pos clears (cache evictions swap_in may have forced) land
        # BEFORE the restore, so they can never wipe the restored rows
        self._drain_released()
        host = np.array([h for h, _ in pairs], np.int32)
        dev = jnp.asarray([d for _, d in pairs], jnp.int32)
        out = dict(self.cache)
        for k, hp in self._host_pool.items():
            if k == "pos":
                out[k] = out[k].at[dev].set(jnp.asarray(hp[host]))
            else:
                out[k] = out[k].at[:, dev].set(jnp.asarray(hp[:, host]))
        self.cache = out
        self.swap_in_bytes += self.mem.blocks_to_bytes(len(pairs))
        self.swap_ins += 1
        slot = self._free_slots.pop()
        r.slot = slot
        self.cache = state_clear_row(self.cache, slot)
        if r.swap_out_time >= 0:
            wait = self._now() - r.swap_out_time
            r.swapped_s += wait
            r.n_swaps += 1
            r.swap_out_time = -1.0
            self.swap_wait_trace.append(wait)
        r.state = RequestState.RUNNING
        self.swapped.pop(0)
        self.active.append(r)
        return True

    def _evict(self, slot: int, r: Request):
        """Evict active[slot] for recompute. `slot` is the index in
        `self.active`; paged mode just releases blocks + state row (O(1)),
        contiguous mode compacts by moving the last row into the hole."""
        self._free_request(r)
        r.state = RequestState.WAITING
        # new life generation (DESIGN §14): in-flight patches recorded
        # against the cleared outputs must not land on the recompute pass
        self._gen[r.rid] = self._gen.get(r.rid, 0) + 1
        r.output_tokens.clear()
        r.tbt_samples.clear()
        # the recompute pass re-probes the prefix index from scratch — the
        # request's own just-freed blocks are prime cache hits (DESIGN §10)
        r.cached_prefix_len = 0
        # recompute: the next serving pass re-attributes TTFT from scratch
        # (a stale prefill_start_time would count the first life — decode
        # included — as prefill service)
        r.prefill_start_time = -1.0
        if self.paged:
            self.active.pop(slot)
        else:
            last = len(self.active) - 1
            if slot != last:
                self._copy_row(slot, last)
                self.active[slot] = self.active[last]
                self.active[slot].slot = slot
            self.active.pop()
        self.waiting.insert(0, r)
        self.preemptions += 1

    def _decode_once(self, rec: _StepRec):
        if self.prefix:
            # COW guard on the position each decode writes (DESIGN §10)
            for r in self.active:
                self._cow_blocks(self.blocks.cow_range(
                    r.rid, r.context_len - 1, r.context_len))
        n = len(self.active)
        ge = [b for b in self.buckets if b >= n]
        bucket = min(ge) if ge else self.max_slots
        # inputs: retired tokens are host ints; un-retired ones (pipeline
        # depth >= 1, or promoted this very interval) are still device
        # scalars and are spliced in without a readback — the VALUES are
        # identical to the synchronous loop's, so the decode graph sees
        # the same inputs bit for bit (DESIGN §14)
        toks: List[int] = []
        pend: List[Tuple[int, Any]] = []
        for i, r in enumerate(self.active):
            v = r.output_tokens[-1]
            if v is None:
                toks.append(0)
                pend.append((i, self._pending_tok[r.rid]))
            else:
                toks.append(v)
        toks += [0] * (bucket - n)
        # the pending token sits at absolute position context_len - 1
        lens = [r.context_len - 1 for r in self.active] + [-1] * (bucket - n)
        tt = jnp.array(toks, jnp.int32)
        for i, dv in pend:
            tt = tt.at[i].set(dv)
        ll = jnp.array(lens, jnp.int32)

        if self.paged:
            rows = jnp.array([r.slot for r in self.active]
                             + [self.n_slots] * (bucket - n), jnp.int32)
            tables = self._tables_for(self.active, pad_to=bucket,
                                      kind="decode")
            logits, self.cache = self._decode_paged_jit(
                self.params, tt, ll, tables, rows, self.cache)
        else:
            sub = cache_take(self.cache, 0, bucket)
            logits, sub = self._decode_jit(self.params, tt, ll, sub)
            self.cache = cache_put(self.cache, sub, 0)

        # the key split is host-side and dispatch-ordered, so sampling
        # stays bit-identical to the synchronous loop at every depth
        self.key, sk = jax.random.split(self.key)
        rec.payload["dec"] = sample(logits[:n], sk, self.temperature)
        rec.n_decode = n
        rec.dispatched = True
        self.batch_trace.append(n)
        self.decode_steps += 1
        self.total_decoded += n

        sampled = rec.payload["dec"]
        finished = []
        grow_failed = []
        for i, r in enumerate(self.active):
            # grow the KV footprint for the NEXT step's write. State-only
            # families (bytes_per_token == 0) hold constant per-request
            # state — growing them would drain free_tokens linearly and
            # starve admission with phantom usage.
            grew = True
            if self.mem.bytes_per_token != 0:
                grew = self.blocks.allocate(r.rid, r.context_len, 1)
            # value-independent bookkeeping (DESIGN §14): the token's
            # VALUE is still in flight, but its existence — length growth,
            # finish at max_new_tokens/max_context — is not. Append a
            # placeholder now, patch it at retirement.
            rec.patches.append((r, len(r.output_tokens),
                                self._gen.get(r.rid, 0), "d", i))
            r.output_tokens.append(None)
            self._pending_tok[r.rid] = sampled[i]
            if len(r.output_tokens) >= r.max_new_tokens \
                    or r.context_len >= self.max_context - 1:
                finished.append(i)
            elif not grew:
                # failed grow: the emitted token has no backing block for
                # its successor — preempt (recompute) instead of silently
                # drifting the allocator
                grow_failed.append(r)
        for i in sorted(finished, reverse=True):
            r = self.active[i]
            r.state = RequestState.FINISHED
            rec.completions.append((r, len(r.output_tokens)))
            self._free_request(r)
            if self.paged:
                self.active.pop(i)
            else:
                last = len(self.active) - 1
                if i != last:
                    self._copy_row(i, last)
                    self.active[i] = self.active[last]
                    self.active[i].slot = i
                self.active.pop()
            self.total_finished += 1
        for r in grow_failed:
            if r in self.active:
                self._evict(self.active.index(r), r)
        # decode grows may have reclaimed cached blocks for reuse
        self._drain_released()

    def _retire_step(self) -> float:
        """Retire the oldest in-flight interval (DESIGN §14): fence on its
        device futures — the timed wait IS the interval's device time,
        the latency the host could not hide — then pull the sampled and
        first tokens in ONE batched transfer, patch their output-token
        placeholders, stamp TTFT/TBT at retirement (timestamps mark
        result availability, not dispatch), apply the interval's deferred
        telemetry feeds, and seal the allocator's shadow epoch. Returns
        the fence wait in seconds."""
        rec = self._inflight.popleft()
        t0 = time.perf_counter()
        # THE pipeline fence: the one block the async loop retains
        jax.block_until_ready(rec.payload)
        dev_s = time.perf_counter() - t0
        # everything is ready — one bulk readback, not per-token syncs
        vals = jax.device_get(rec.payload)
        dt_ms = dev_s * 1e3
        now = self._now()
        dec = vals.get("dec")
        first = vals.get("first", ())
        for r, idx, gen, kind, k in rec.patches:
            if self._gen.get(r.rid, 0) != gen:
                continue   # evicted since dispatch: that life's outputs
                           # were cleared; recompute re-emits them
            if idx < len(r.output_tokens) and r.output_tokens[idx] is None:
                r.output_tokens[idx] = int(dec[k] if kind == "d"
                                           else first[k])
            if kind == "d":
                # TBT sample = the marginal fence wait this interval cost
                r.tbt_samples.append(dt_ms)
        if rec.lane_tokens is not None:
            self.tel.on_prefill_interval(rec.lane_tokens, self.n_lanes)
        for r, feed, queue_s, t_ps in rec.firsts:
            r.first_token_time = now
            self.ttft_trace.append(now - r.arrival_time)
            if feed:
                self.tel.on_first_token(queue_s, now - t_ps)
        if rec.n_decode:
            self.tel.on_decode_step(dt_ms, rec.n_decode)
            self.tbt_trace.append(dt_ms)
            self._sla_steps += 1
            if self.serve.d_sla_ms <= 0 or dt_ms <= self.serve.d_sla_ms \
                    + self.serve.eps_d_ms:
                self._sla_ok += 1
        for r, n_out in rec.completions:
            r.finish_time = now
            # goodput verdict (DESIGN §15): stamped at retirement — the
            # firsts loop above has already finalized first_token_time
            if r.stamp_sla(self.serve.ttft_sla_s, self.serve.tbt_sla_ms):
                self.sla_requests_met += 1
                self.goodput_tokens += n_out
            self.tel.on_completion(n_out)
        # seal the shadow epoch: blocks freed since the last retirement
        # are safe for arbitrary reuse now that the step that could still
        # read them has been fenced; open the next epoch for the frees
        # the remaining in-flight interval(s) will record
        self.blocks.shadow_commit()
        self.blocks.shadow_begin()
        return dev_s

    # -- metrics ---------------------------------------------------------------
    def summary(self) -> Dict[str, float]:
        el = self._now()
        occ = self.tel.lane_occ
        tq, _ = self.tel.ttft_queue.get()
        tp, _ = self.tel.ttft_prefill.get()
        tbts = sorted(self.tbt_trace)
        ttfts = sorted(self.ttft_trace)
        return {
            "throughput_tok_s": self.total_decoded / max(el, 1e-9),
            "total_tokens": float(self.total_decoded),
            "duration_s": el,
            # mesh-sharded serving (DESIGN §12): effective model-axis
            # shards of the KV pool and the resulting token capacity
            "model_shards": float(self.model_shards),
            "pool_tokens": float(self.mem.eta),
            "decode_steps": self.decode_steps,
            "mean_batch": (sum(self.batch_trace) / len(self.batch_trace))
            if self.batch_trace else 0.0,
            "tbt_ms_mean": (sum(self.tbt_trace) / len(self.tbt_trace))
            if self.tbt_trace else 0.0,
            "tbt_ms_p95": tbts[int(0.95 * (len(tbts) - 1))] if tbts else 0.0,
            "sla_attainment": (self._sla_ok / self._sla_steps)
            if self._sla_steps else 0.0,
            # per-request goodput SLOs (DESIGN §15): throughput counting
            # only SLA-met requests' tokens
            "goodput_tok_s": self.goodput_tokens / max(el, 1e-9),
            "goodput_tokens": float(self.goodput_tokens),
            "sla_requests_met": self.sla_requests_met,
            "request_sla_attainment": self.sla_requests_met
            / max(self.total_finished + self.rejected, 1),
            # host-vs-device interval split (DESIGN §14)
            "step_host_s_mean": (sum(self.step_host_trace)
                                 / len(self.step_host_trace))
            if self.step_host_trace else 0.0,
            "step_device_s_mean": (sum(self.step_device_trace)
                                   / len(self.step_device_trace))
            if self.step_device_trace else 0.0,
            "finished": self.total_finished,
            "admitted": self.admitted_total,
            "preemptions": self.preemptions,
            "oom_events": self.oom_events,
            "rejected": self.rejected,
            # two-tier swap (DESIGN §11)
            "swap_outs": self.swap_outs,
            "swap_ins": self.swap_ins,
            "swap_out_bytes": float(self.swap_out_bytes),
            "swap_in_bytes": float(self.swap_in_bytes),
            "swapped_peak": float(self.blocks.swapped_peak),
            "swap_latency_s_mean": (sum(self.swap_wait_trace)
                                    / len(self.swap_wait_trace))
            if self.swap_wait_trace else 0.0,
            # contiguous-layout row copies; 0 under paged_kv (DESIGN §9)
            "copy_rows": float(self.copy_rows),
            "copy_bytes": float(self.copy_bytes),
            # prefix sharing (DESIGN §10)
            "prefix_hit_rate": self.blocks.prefix_hit_rate,
            "prefix_hit_tokens": float(self.blocks.prefix_hit_tokens),
            "prefix_query_tokens": float(self.blocks.prefix_query_tokens),
            "cached_blocks": float(self.blocks.cached_blocks),
            "cache_evictions": float(self.blocks.cache_evictions),
            "logical_used_tokens": float(self.blocks.logical_used_tokens),
            "physical_used_tokens": float(self.blocks.physical_used_tokens),
            "logical_used_bytes": float(self.mem.tokens_to_bytes(
                self.blocks.logical_used_tokens)),
            "physical_used_bytes": float(self.mem.tokens_to_bytes(
                self.blocks.physical_used_tokens)),
            # PD fusion (DESIGN §6)
            "prefill_lane_occupancy": (sum(occ) / len(occ)) if occ else 0.0,
            "prefill_tokens": float(self.tel.prefill_tokens_total),
            "ttft_queue_s_mean": tq,
            "ttft_prefill_s_mean": tp,
            "ttft_mean_s": (sum(ttfts) / len(ttfts)) if ttfts else 0.0,
            "ttft_p90_s": ttfts[int(0.9 * (len(ttfts) - 1))]
            if ttfts else 0.0,
        }
