"""Continuous-batching serving engine over a real JAX model.

Runs the same controller stack as the simulator (Telemetry -> Policy ->
BlockManager) with actual jit-compiled prefill/decode steps and wall-clock
TBT feedback. Batch sizes are bucketized (TPU/XLA static shapes — DESIGN §3):
the decode step runs on the smallest compiled bucket >= active requests, with
inactive rows masked via position -1.

Intended for reduced-config models on CPU (tests, Fig-3-style curves) and as
the production template for TPU serving (launch/serve.py).
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig, ServeConfig
from repro.core.batching import make_policy
from repro.core.memory_model import MemoryModel
from repro.core.telemetry import Telemetry
from repro.models.model import Model
from repro.serving.kv_cache import BlockManager
from repro.serving.request import Request, RequestState
from repro.serving.sampling import sample


def _batch_axis(name: str) -> int:
    return 0 if name == "pos" else 1


def cache_take(cache: Dict[str, Any], start: int, n: int) -> Dict[str, Any]:
    return {k: jax.lax.slice_in_dim(v, start, start + n, axis=_batch_axis(k))
            for k, v in cache.items()}


def cache_put(cache: Dict[str, Any], sub: Dict[str, Any],
              start: int) -> Dict[str, Any]:
    return {k: jax.lax.dynamic_update_slice_in_dim(
        v, sub[k], start, axis=_batch_axis(k)) for k, v in cache.items()}


def cache_copy_row(cache: Dict[str, Any], dst: int, src: int) -> Dict[str, Any]:
    out = {}
    for k, v in cache.items():
        ax = _batch_axis(k)
        row = jax.lax.index_in_dim(v, src, axis=ax, keepdims=False)
        idx = [slice(None)] * v.ndim
        idx[ax] = dst
        out[k] = v.at[tuple(idx)].set(row)
    return out


def cache_clear_row(cache: Dict[str, Any], i: int) -> Dict[str, Any]:
    out = dict(cache)
    if "pos" in cache:
        out["pos"] = cache["pos"].at[i].set(-1)
    for k in ("conv", "rec", "ssm"):
        if k in cache:
            out[k] = cache[k].at[:, i].set(0)
    return out


class Engine:
    def __init__(self, model: Model, params, serve: ServeConfig,
                 max_context: int = 256,
                 buckets: Tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64),
                 prefill_chunk: int = 32, enc_len: int = 0, seed: int = 0,
                 temperature: float = 0.0):
        self.model = model
        self.cfg: ModelConfig = model.cfg
        self.serve = serve
        self.max_context = max_context
        self.buckets = tuple(sorted(b for b in buckets if b <= serve.b_max)) \
            or (serve.b_max,)
        self.max_slots = max(self.buckets)
        self.prefill_chunk = prefill_chunk
        self.params = params
        self.enc_len = enc_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)

        # +1 spare physical row: the PD-fusion prefilling request lives
        # outside every decode bucket so masked decode steps can never
        # touch its (stateful) cache row
        self.cache = model.init_cache(self.max_slots + 1, max_context,
                                      enc_len=enc_len,
                                      prefill_chunk=prefill_chunk)
        eta = serve.kv_pool_tokens or self.max_slots * max_context
        self.mem = MemoryModel(self.cfg, hbm_budget_bytes=0,
                               eps_m=serve.eps_m,
                               block_size=serve.block_size, eta_tokens=eta)
        self.blocks = BlockManager(self.mem.eta, serve.block_size)
        self.tel = Telemetry()
        self.policy = make_policy(serve, self.mem)

        self.waiting: List[Request] = []
        self.active: List[Request] = []          # compact: slot i = active[i]
        # PD fusion: head-of-line request being chunk-prefilled; lives in
        # the dedicated spare physical row (slot == max_slots)
        self.prefilling: List[Request] = []
        self.now0 = time.perf_counter()
        self._next_rid = 0
        self.total_decoded = 0
        self.total_finished = 0
        self.preemptions = 0
        self.decode_steps = 0
        self.batch_trace: List[int] = []
        self.tbt_trace: List[float] = []

        self._decode_jit = jax.jit(self._decode_fn)
        self._prefill_jit = jax.jit(self._prefill_fn)

    # -- jit'd steps ----------------------------------------------------------
    def _decode_fn(self, params, tokens, seq_lens, cache):
        return self.model.decode_step(params, tokens, seq_lens, cache)

    def _prefill_fn(self, params, tokens, positions, cache, extras):
        return self.model.prefill(params, tokens, positions, cache, extras)

    # -- public API -------------------------------------------------------------
    def submit(self, prompt_tokens: List[int], max_new_tokens: int = 0,
               extras: Optional[Dict[str, jnp.ndarray]] = None,
               arrival_time: Optional[float] = None) -> Request:
        t = arrival_time if arrival_time is not None else self._now()
        mx = max_new_tokens or self.serve.max_new_tokens
        mx = min(mx, self.max_context - len(prompt_tokens) - 1)
        r = Request(rid=self._next_rid, arrival_time=t,
                    prompt_tokens=list(prompt_tokens), max_new_tokens=mx)
        self._next_rid += 1
        r.extras = extras
        self.waiting.append(r)
        self.tel.on_arrival(t, r.prompt_len)
        return r

    def warmup(self):
        """Compile decode buckets + prefill graph so TBT feedback is clean."""
        for b in self.buckets:
            sub = cache_take(self.cache, 0, b)
            toks = jnp.zeros((b,), jnp.int32)
            lens = jnp.full((b,), -1, jnp.int32)
            jax.block_until_ready(self._decode_jit(self.params, toks, lens, sub))
        sub = cache_take(self.cache, 0, 1)
        tt = jnp.zeros((1, self.prefill_chunk), jnp.int32)
        pos = jnp.full((1, self.prefill_chunk), -1, jnp.int32)
        jax.block_until_ready(
            self._prefill_jit(self.params, tt, pos, sub, None))

    def _now(self) -> float:
        return time.perf_counter() - self.now0

    # -- scheduling interval -------------------------------------------------------
    def step(self) -> bool:
        """One scheduling interval. Returns False when fully idle."""
        if not self.waiting and not self.active and not self.prefilling:
            return False
        tel = self.tel.snapshot(
            now=self._now(),
            n_prefill=len(self.waiting) + len(self.prefilling),
            n_decode=len(self.active), free_tokens=self.blocks.free_tokens)
        decision = self.policy.step(tel)
        cap = min(decision.max_batch, self.max_slots)

        # admission
        while self.waiting \
                and len(self.active) + len(self.prefilling) < cap:
            r = self.waiting[0]
            need = r.prompt_len + 1
            if self.mem.bytes_per_token == 0:
                need = self.serve.block_size
            if not self.blocks.allocate(r.rid, 0, need):
                break
            self.waiting.pop(0)
            if self.serve.chunked_prefill:
                r.state = RequestState.PREFILLING
                r.prefill_pos = 0
                self.prefilling.append(r)
            else:
                self._prefill_request(r)

        self._preempt_if_needed()
        if self.serve.chunked_prefill:
            # PD fusion: one fused interval = a prefill chunk (within the
            # controller's token budget) + the decode batch; TBT accounts
            # for both (the paper's adaptive-chunk-size scenario)
            budget = decision.chunk_budget \
                or self.serve.chunk_budget_tokens
            chunk_ms = self._advance_prefill(budget)
            if self.active:
                self._decode_once(extra_ms=chunk_ms)
        elif self.active:
            self._decode_once()
        return True

    # -- PD fusion internals ----------------------------------------------------
    def _advance_prefill(self, budget_tokens: int) -> float:
        """Advance the head-of-line prefilling request by one chunk
        (<= budget). Returns wall-clock ms spent."""
        if not self.prefilling or budget_tokens <= 0:
            return 0.0
        r = self.prefilling[0]
        slot = self.max_slots          # dedicated spare row
        if r.prefill_pos == 0 and r.slot != slot:
            self.cache = cache_clear_row(self.cache, slot)
            r.slot = slot
        take = min(budget_tokens, self.prefill_chunk,
                   r.prompt_len - r.prefill_pos)
        piece = r.prompt_tokens[r.prefill_pos:r.prefill_pos + take]
        tt = jnp.array([piece], jnp.int32)
        pos = jnp.array([list(range(r.prefill_pos,
                                    r.prefill_pos + take))], jnp.int32)
        ex = getattr(r, "extras", None) if r.prefill_pos == 0 else None
        sub = cache_take(self.cache, slot, 1)
        t0 = time.perf_counter()
        logits, sub = self._prefill_jit(self.params, tt, pos, sub, ex)
        logits = jax.block_until_ready(logits)
        dt_ms = (time.perf_counter() - t0) * 1e3
        self.cache = cache_put(self.cache, sub, slot)
        r.prefill_pos += take
        if r.prefill_pos >= r.prompt_len:
            self.prefilling.pop(0)
            # promote: move the finished row into the running region
            dst = len(self.active)
            self.cache = cache_copy_row(self.cache, dst, slot)
            r.slot = dst
            r.state = RequestState.RUNNING
            r.first_token_time = self._now()
            r.output_tokens.append(int(jnp.argmax(logits[0, take - 1])))
            self.active.append(r)
        return dt_ms

    def run(self, max_steps: int = 100_000) -> int:
        steps = 0
        while self.step() and steps < max_steps:
            steps += 1
        return steps

    # -- internals ---------------------------------------------------------------
    def _prefill_request(self, r: Request):
        slot = len(self.active)
        r.slot = slot
        r.state = RequestState.PREFILLING
        self.cache = cache_clear_row(self.cache, slot)
        chunk = self.prefill_chunk
        toks = r.prompt_tokens
        sub = cache_take(self.cache, slot, 1)
        extras = getattr(r, "extras", None)
        last_logits = None
        # exact-size chunks: stateful families (SSM conv/recurrence) must not
        # see pad tokens — full chunks + one exact-size tail call (jit caches
        # one graph per distinct tail length)
        pieces = [(s, toks[s:s + chunk]) for s in range(0, len(toks), chunk)]
        for start, piece in pieces:
            tt = jnp.array([piece], jnp.int32)
            pos = jnp.array([list(range(start, start + len(piece)))], jnp.int32)
            ex = extras if start == 0 else None
            logits, sub = self._prefill_jit(self.params, tt, pos, sub, ex)
            last_logits = logits[0, len(piece) - 1]
        self.cache = cache_put(self.cache, sub, slot)
        r.state = RequestState.RUNNING
        r.first_token_time = self._now()
        r.output_tokens.append(int(jnp.argmax(last_logits)))
        self.active.append(r)

    def _preempt_if_needed(self):
        while self.active:
            need = sum(self.blocks.blocks_needed(r.context_len, 1, r.rid)
                       for r in self.active)
            if need <= self.blocks.free_blocks:
                return
            victim = self.active[-1]  # newest (vLLM recompute policy)
            self._evict(len(self.active) - 1, victim)

    def _evict(self, slot: int, r: Request):
        self.blocks.free(r.rid)
        r.state = RequestState.WAITING
        r.output_tokens.clear()
        r.tbt_samples.clear()
        last = len(self.active) - 1
        if slot != last:
            self.cache = cache_copy_row(self.cache, slot, last)
            self.active[slot] = self.active[last]
            self.active[slot].slot = slot
        self.active.pop()
        self.waiting.insert(0, r)
        self.preemptions += 1

    def _decode_once(self, extra_ms: float = 0.0):
        n = len(self.active)
        ge = [b for b in self.buckets if b >= n]
        bucket = min(ge) if ge else self.max_slots
        toks = [r.output_tokens[-1] for r in self.active] + [0] * (bucket - n)
        # the pending token sits at absolute position context_len - 1
        lens = [r.context_len - 1 for r in self.active] + [-1] * (bucket - n)
        tt = jnp.array(toks, jnp.int32)
        ll = jnp.array(lens, jnp.int32)
        sub = cache_take(self.cache, 0, bucket)

        t0 = time.perf_counter()
        logits, sub = self._decode_jit(self.params, tt, ll, sub)
        logits = jax.block_until_ready(logits)
        dt_ms = (time.perf_counter() - t0) * 1e3 + extra_ms

        self.cache = cache_put(self.cache, sub, 0)
        self.key, sk = jax.random.split(self.key)
        next_toks = [int(x) for x in sample(logits[:n], sk, self.temperature)]

        self.tel.on_decode_step(dt_ms, n)
        self.tbt_trace.append(dt_ms)
        self.batch_trace.append(n)
        self.decode_steps += 1
        self.total_decoded += n

        finished = []
        for i, r in enumerate(self.active):
            self.blocks.allocate(r.rid, r.context_len, 1)
            r.output_tokens.append(next_toks[i])
            r.tbt_samples.append(dt_ms)
            if len(r.output_tokens) >= r.max_new_tokens \
                    or r.context_len >= self.max_context - 1:
                finished.append(i)
        for i in sorted(finished, reverse=True):
            r = self.active[i]
            r.state = RequestState.FINISHED
            r.finish_time = self._now()
            self.tel.on_completion(len(r.output_tokens))
            self.blocks.free(r.rid)
            last = len(self.active) - 1
            if i != last:
                self.cache = cache_copy_row(self.cache, i, last)
                self.active[i] = self.active[last]
                self.active[i].slot = i
            self.active.pop()
            self.total_finished += 1

    # -- metrics ---------------------------------------------------------------
    def summary(self) -> Dict[str, float]:
        el = self._now()
        return {
            "throughput_tok_s": self.total_decoded / max(el, 1e-9),
            "decode_steps": self.decode_steps,
            "mean_batch": (sum(self.batch_trace) / len(self.batch_trace))
            if self.batch_trace else 0.0,
            "tbt_ms_mean": (sum(self.tbt_trace) / len(self.tbt_trace))
            if self.tbt_trace else 0.0,
            "finished": self.total_finished,
            "preemptions": self.preemptions,
        }
