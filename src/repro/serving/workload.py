"""Non-stationary workloads: the paper's lambda(t) dynamics (§II-B).

Generators produce (arrival_time, prompt_len, output_len) streams for the
simulator: Poisson baseline, square-wave bursts (traffic spikes), diurnal
sinusoid, and replay from a JSONL trace file.
"""
from __future__ import annotations

import json
import math
import random
from typing import Iterator, List, Tuple

from repro.serving.request import Request
from repro.serving.sim import LengthDist, ServingSimulator

Arrival = Tuple[float, int, int]   # (t, l_in, l_out)


def poisson(rate: float, n: int, lengths: LengthDist,
            seed: int = 0) -> List[Arrival]:
    rng = random.Random(seed)
    t, out = 0.0, []
    for _ in range(n):
        li, lo = lengths.sample(rng)
        out.append((t, li, lo))
        t += rng.expovariate(rate)
    return out


def bursty(base_rate: float, burst_rate: float, period_s: float,
           duty: float, n: int, lengths: LengthDist,
           seed: int = 0) -> List[Arrival]:
    """Square-wave lambda(t): base_rate, spiking to burst_rate for
    duty*period every period."""
    rng = random.Random(seed)
    t, out = 0.0, []
    for _ in range(n):
        phase = (t % period_s) / period_s
        rate = burst_rate if phase < duty else base_rate
        li, lo = lengths.sample(rng)
        out.append((t, li, lo))
        t += rng.expovariate(rate)
    return out


def diurnal(mean_rate: float, amplitude: float, period_s: float, n: int,
            lengths: LengthDist, seed: int = 0) -> List[Arrival]:
    rng = random.Random(seed)
    t, out = 0.0, []
    for _ in range(n):
        rate = max(mean_rate * (1 + amplitude *
                                math.sin(2 * math.pi * t / period_s)), 1e-3)
        li, lo = lengths.sample(rng)
        out.append((t, li, lo))
        t += rng.expovariate(rate)
    return out


def save_trace(path: str, arrivals: List[Arrival]) -> None:
    with open(path, "w") as f:
        for t, li, lo in arrivals:
            f.write(json.dumps({"t": t, "l_in": li, "l_out": lo}) + "\n")


def load_trace(path: str) -> List[Arrival]:
    out = []
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            out.append((float(r["t"]), int(r["l_in"]), int(r["l_out"])))
    return out


def feed(sim: ServingSimulator, arrivals: List[Arrival]) -> None:
    """Inject a pre-built arrival stream into a simulator."""
    for i, (t, li, lo) in enumerate(arrivals):
        sim.waiting.append(Request(
            rid=i, arrival_time=t, prompt_len=li, true_output_len=lo,
            max_new_tokens=sim.serve.max_new_tokens))
    sim.waiting.sort(key=lambda r: r.arrival_time)
    sim._all.extend(sim.waiting)
