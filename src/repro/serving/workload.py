"""Non-stationary workloads: the paper's lambda(t) dynamics (§II-B).

Generators produce (arrival_time, prompt_len, output_len) streams for the
simulator: Poisson baseline, square-wave bursts (traffic spikes), diurnal
sinusoid, and replay from a JSONL trace file.

`shared_prefix` produces token-level streams (arrival_time, prompt_tokens,
output_len) for the prefix-sharing path (DESIGN §10): prompts draw a system
prompt from a fixed pool and conversations re-arrive multi-turn, each next
turn's prompt extending the previous turn's full transcript — the traffic
shape where vLLM-style prefix caching pays off. The same stream drives the
simulator (`feed_tokens`) and the real engine (`benchmarks/
prefix_caching.py`), so hit rates are directly comparable.
"""
from __future__ import annotations

import json
import math
import random
from typing import Iterator, List, Tuple

from repro.serving.request import Request
from repro.serving.sim import LengthDist, ServingSimulator

Arrival = Tuple[float, int, int]            # (t, l_in, l_out)
TokenArrival = Tuple[float, List[int], int]  # (t, prompt_tokens, l_out)


def poisson(rate: float, n: int, lengths: LengthDist,
            seed: int = 0) -> List[Arrival]:
    rng = random.Random(seed)
    t, out = 0.0, []
    for _ in range(n):
        li, lo = lengths.sample(rng)
        out.append((t, li, lo))
        t += rng.expovariate(rate)
    return out


def bursty(base_rate: float, burst_rate: float, period_s: float,
           duty: float, n: int, lengths: LengthDist,
           seed: int = 0) -> List[Arrival]:
    """Square-wave lambda(t): base_rate, spiking to burst_rate for
    duty*period every period."""
    rng = random.Random(seed)
    t, out = 0.0, []
    for _ in range(n):
        phase = (t % period_s) / period_s
        rate = burst_rate if phase < duty else base_rate
        li, lo = lengths.sample(rng)
        out.append((t, li, lo))
        t += rng.expovariate(rate)
    return out


def diurnal(mean_rate: float, amplitude: float, period_s: float, n: int,
            lengths: LengthDist, seed: int = 0) -> List[Arrival]:
    rng = random.Random(seed)
    t, out = 0.0, []
    for _ in range(n):
        rate = max(mean_rate * (1 + amplitude *
                                math.sin(2 * math.pi * t / period_s)), 1e-3)
        li, lo = lengths.sample(rng)
        out.append((t, li, lo))
        t += rng.expovariate(rate)
    return out


def shared_prefix(rate: float, n: int, *, vocab_size: int = 1000,
                  n_system_prompts: int = 4, system_len: int = 64,
                  user_len: Tuple[int, int] = (8, 32),
                  mean_out: float = 24.0, p_followup: float = 0.5,
                  max_turns: int = 4, turn_gap_s: float = 5.0,
                  seed: int = 0) -> List[TokenArrival]:
    """Shared-system-prompt, multi-turn token workload (DESIGN §10).

    Each conversation opens with one of `n_system_prompts` fixed system
    prompts (`system_len` tokens, deterministic per pool entry) plus fresh
    user tokens. With probability `p_followup` (up to `max_turns` turns) it
    re-arrives `turn_gap_s` later, its next prompt = the previous prompt +
    the previous turn's transcript (synthetic assistant tokens of the
    sampled output length) + a new user utterance — the traffic where every
    turn's prefill is dominated by already-seen tokens. Poisson arrivals at
    `rate` for conversation openers; `n` total requests."""
    rng = random.Random(seed)
    pool = [[rng.randrange(vocab_size) for _ in range(system_len)]
            for _ in range(n_system_prompts)]

    def utterance():
        return [rng.randrange(vocab_size)
                for _ in range(rng.randint(*user_len))]

    def out_len():
        return max(1, int(rng.expovariate(1.0 / mean_out)))

    out: List[TokenArrival] = []
    t = 0.0
    while len(out) < n:
        prompt = list(rng.choice(pool)) + utterance()
        turn_t = t
        for turn in range(max_turns):
            lo = out_len()
            out.append((turn_t, list(prompt), lo))
            if len(out) >= n or rng.random() >= p_followup:
                break
            # next turn extends the transcript: previous prompt + synthetic
            # assistant reply + a fresh user utterance
            prompt = prompt + [rng.randrange(vocab_size) for _ in range(lo)] \
                + utterance()
            turn_t += turn_gap_s * (1.0 + rng.random())
        t += rng.expovariate(rate)
    out.sort(key=lambda a: a[0])
    return out[:n]


def feed_tokens(sim: ServingSimulator, arrivals: List[TokenArrival]) -> None:
    """Inject a token-level arrival stream (prefix-sharing workloads): the
    sim's BlockManager matches/registers these prompts exactly like the
    engine does (DESIGN §10)."""
    base = len(sim._all)
    new = [Request(rid=base + i, arrival_time=t, prompt_tokens=list(toks),
                   true_output_len=lo, max_new_tokens=sim.serve.max_new_tokens)
           for i, (t, toks, lo) in enumerate(arrivals)]
    sim.waiting.extend(new)
    sim.waiting.sort(key=lambda r: r.arrival_time)
    sim._all.extend(new)


def save_trace(path: str, arrivals: List[Arrival]) -> None:
    with open(path, "w") as f:
        for t, li, lo in arrivals:
            f.write(json.dumps({"t": t, "l_in": li, "l_out": lo}) + "\n")


def load_trace(path: str) -> List[Arrival]:
    out = []
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            out.append((float(r["t"]), int(r["l_in"]), int(r["l_out"])))
    return out


def feed(sim: ServingSimulator, arrivals: List[Arrival]) -> None:
    """Inject a pre-built arrival stream into a simulator."""
    for i, (t, li, lo) in enumerate(arrivals):
        sim.waiting.append(Request(
            rid=i, arrival_time=t, prompt_len=li, true_output_len=lo,
            max_new_tokens=sim.serve.max_new_tokens))
    sim.waiting.sort(key=lambda r: r.arrival_time)
    sim._all.extend(sim.waiting)
