"""Non-stationary workloads + trace replay: the paper's lambda(t) dynamics
(§II-B) and production-shaped traces (DESIGN §15).

Generators produce (arrival_time, prompt_len, output_len) streams for the
simulator: Poisson baseline, square-wave bursts (traffic spikes), diurnal
sinusoid, and replay from a JSONL trace file. The non-homogeneous
generators sample by Lewis–Shedler thinning — candidate gaps at the peak
rate, accepted with probability lambda(t)/max_rate — so realized
per-window rates match lambda(t) even when a quiet-rate gap would have
stepped clean over an entire burst window.

`shared_prefix` produces token-level streams (arrival_time, prompt_tokens,
output_len) for the prefix-sharing path (DESIGN §10): prompts draw a system
prompt from a fixed pool and conversations re-arrive multi-turn, each next
turn's prompt extending the previous turn's full transcript — the traffic
shape where vLLM-style prefix caching pays off. The same stream drives the
simulator (`feed_tokens`) and the real engine (`benchmarks/
prefix_caching.py`), so hit rates are directly comparable.

Trace replay (DESIGN §15) unifies both stream shapes under one versioned,
validated JSONL schema: a header line `{"schema": "repro-trace",
"version": 1, "kind": "lengths"|"tokens"}` followed by one record per
request (`t`, `l_out`, and `l_in` or `tokens`; optional `id`/`parent_id`
for ShareGPT-style multi-turn conversation structure). `save_trace`/
`load_trace` roundtrip Arrival and TokenArrival streams alike;
`load_trace_events` returns validated `TraceEvent`s with `path:line`
errors on malformed records; `reference_trace` synthesizes a bundled
ShareGPT/Azure-LLM-shaped trace so CI never needs an external download.
"""
from __future__ import annotations

import dataclasses
import json
import math
import random
import warnings
from typing import Callable, List, Optional, Tuple

from repro.serving.request import Request
from repro.serving.sim import LengthDist, ServingSimulator, _lognorm

Arrival = Tuple[float, int, int]            # (t, l_in, l_out)
TokenArrival = Tuple[float, List[int], int]  # (t, prompt_tokens, l_out)

TRACE_SCHEMA = "repro-trace"
TRACE_VERSION = 1


def poisson(rate: float, n: int, lengths: LengthDist,
            seed: int = 0) -> List[Arrival]:
    rng = random.Random(seed)
    t, out = 0.0, []
    for _ in range(n):
        li, lo = lengths.sample(rng)
        out.append((t, li, lo))
        t += rng.expovariate(rate)
    return out


def _thinned_arrivals(rate_fn: Callable[[float], float], max_rate: float,
                      n: int, lengths: LengthDist,
                      rng: random.Random) -> List[Arrival]:
    """Lewis–Shedler thinning for a non-homogeneous Poisson process:
    candidate arrivals at the constant peak rate, each kept with
    probability lambda(t)/max_rate. Unlike drawing each gap from lambda
    at the current instant, no window of elevated rate can be stepped
    over — the realized rate in every window matches lambda(t)."""
    t, out = 0.0, []
    while len(out) < n:
        t += rng.expovariate(max_rate)
        if rng.random() * max_rate <= rate_fn(t):
            li, lo = lengths.sample(rng)
            out.append((t, li, lo))
    return out


def bursty(base_rate: float, burst_rate: float, period_s: float,
           duty: float, n: int, lengths: LengthDist,
           seed: int = 0) -> List[Arrival]:
    """Square-wave lambda(t): base_rate, spiking to burst_rate for
    duty*period every period. Sampled by Lewis–Shedler thinning so a
    quiet-rate gap cannot skip a whole burst window."""
    rng = random.Random(seed)

    def lam(t: float) -> float:
        return burst_rate if (t % period_s) / period_s < duty else base_rate

    return _thinned_arrivals(lam, max(base_rate, burst_rate), n, lengths,
                             rng)


def diurnal(mean_rate: float, amplitude: float, period_s: float, n: int,
            lengths: LengthDist, seed: int = 0) -> List[Arrival]:
    rng = random.Random(seed)

    def lam(t: float) -> float:
        return max(mean_rate * (1 + amplitude *
                                math.sin(2 * math.pi * t / period_s)), 1e-3)

    max_rate = max(mean_rate * (1 + abs(amplitude)), 1e-3)
    return _thinned_arrivals(lam, max_rate, n, lengths, rng)


def shared_prefix(rate: float, n: int, *, vocab_size: int = 1000,
                  n_system_prompts: int = 4, system_len: int = 64,
                  user_len: Tuple[int, int] = (8, 32),
                  mean_out: float = 24.0, p_followup: float = 0.5,
                  max_turns: int = 4, turn_gap_s: float = 5.0,
                  seed: int = 0) -> List[TokenArrival]:
    """Shared-system-prompt, multi-turn token workload (DESIGN §10).

    Each conversation opens with one of `n_system_prompts` fixed system
    prompts (`system_len` tokens, deterministic per pool entry) plus fresh
    user tokens. With probability `p_followup` (up to `max_turns` turns) it
    re-arrives `turn_gap_s` later, its next prompt = the previous prompt +
    the previous turn's transcript (synthetic assistant tokens of the
    sampled output length) + a new user utterance — the traffic where every
    turn's prefill is dominated by already-seen tokens. Poisson arrivals at
    `rate` for conversation openers; `n` total requests."""
    rng = random.Random(seed)
    pool = [[rng.randrange(vocab_size) for _ in range(system_len)]
            for _ in range(n_system_prompts)]

    def utterance():
        return [rng.randrange(vocab_size)
                for _ in range(rng.randint(*user_len))]

    def out_len():
        return max(1, int(rng.expovariate(1.0 / mean_out)))

    out: List[TokenArrival] = []
    t = 0.0
    while len(out) < n:
        prompt = list(rng.choice(pool)) + utterance()
        turn_t = t
        for turn in range(max_turns):
            lo = out_len()
            out.append((turn_t, list(prompt), lo))
            if len(out) >= n or rng.random() >= p_followup:
                break
            # next turn extends the transcript: previous prompt + synthetic
            # assistant reply + a fresh user utterance
            prompt = prompt + [rng.randrange(vocab_size) for _ in range(lo)] \
                + utterance()
            turn_t += turn_gap_s * (1.0 + rng.random())
        t += rng.expovariate(rate)
    out.sort(key=lambda a: a[0])
    return out[:n]


def feed_tokens(sim: ServingSimulator, arrivals: List[TokenArrival]) -> None:
    """Inject a token-level arrival stream (prefix-sharing workloads): the
    sim's BlockManager matches/registers these prompts exactly like the
    engine does (DESIGN §10)."""
    base = len(sim._all)
    new = [Request(rid=base + i, arrival_time=t, prompt_tokens=list(toks),
                   true_output_len=lo, max_new_tokens=sim.serve.max_new_tokens)
           for i, (t, toks, lo) in enumerate(arrivals)]
    sim.waiting.extend(new)
    sim.waiting.sort(key=lambda r: r.arrival_time)
    sim._all.extend(new)


def feed(sim: ServingSimulator, arrivals: List[Arrival]) -> None:
    """Inject a pre-built arrival stream into a simulator. Safe on a sim
    that already holds requests, and safe to call repeatedly: rids are
    offset past the existing population and only the NEW requests extend
    the sim's bookkeeping (`_all`), so TTFT/goodput aggregation never sees
    duplicate or colliding entries."""
    base = len(sim._all)
    new = [Request(rid=base + i, arrival_time=t, prompt_len=li,
                   true_output_len=lo,
                   max_new_tokens=sim.serve.max_new_tokens)
           for i, (t, li, lo) in enumerate(arrivals)]
    sim.waiting.extend(new)
    sim.waiting.sort(key=lambda r: r.arrival_time)
    sim._all.extend(new)


# ---------------------------------------------------------------------------
# trace replay (DESIGN §15)


class TraceFormatError(ValueError):
    """Malformed trace file: message carries `path:line` context."""


@dataclasses.dataclass
class TraceEvent:
    """One request of a replayable trace (DESIGN §15).

    `l_in` always holds the prompt length; token-level records carry the
    prompt itself in `tokens`. `parent_id` links multi-turn conversation
    structure (the previous turn of the same conversation) and must
    reference an earlier record."""
    t: float
    l_out: int
    l_in: int = 0
    tokens: Optional[List[int]] = None
    id: Optional[int] = None
    parent_id: Optional[int] = None

    @property
    def prompt_len(self) -> int:
        return len(self.tokens) if self.tokens is not None else self.l_in


def _as_events(arrivals) -> List[TraceEvent]:
    """Normalize Arrival tuples, TokenArrival tuples, or TraceEvents into
    TraceEvents (tuples get sequential ids; events keep theirs)."""
    evs: List[TraceEvent] = []
    for i, a in enumerate(arrivals):
        if isinstance(a, TraceEvent):
            evs.append(a if a.id is not None
                       else dataclasses.replace(a, id=i))
            continue
        t, mid, lo = a
        if isinstance(mid, (list, tuple)):
            evs.append(TraceEvent(t=float(t), l_out=int(lo), l_in=len(mid),
                                  tokens=list(mid), id=i))
        else:
            evs.append(TraceEvent(t=float(t), l_out=int(lo), l_in=int(mid),
                                  id=i))
    return evs


def save_trace(path: str, arrivals) -> None:
    """Write a versioned repro-trace JSONL file (DESIGN §15): one header
    line (schema/version/kind) then one record per request. Accepts
    Arrival tuples, TokenArrival tuples, or TraceEvents (multi-turn
    `parent_id` links preserved); the kind is `tokens` iff any record
    carries prompt tokens."""
    evs = _as_events(list(arrivals))
    kind = "tokens" if any(e.tokens is not None for e in evs) else "lengths"
    with open(path, "w") as f:
        f.write(json.dumps({"schema": TRACE_SCHEMA,
                            "version": TRACE_VERSION, "kind": kind}) + "\n")
        for e in evs:
            rec = {"id": e.id, "t": e.t, "l_out": e.l_out}
            if e.tokens is not None:
                rec["l_in"] = len(e.tokens)
                rec["tokens"] = list(e.tokens)
            else:
                rec["l_in"] = e.l_in
            if e.parent_id is not None:
                rec["parent_id"] = e.parent_id
            f.write(json.dumps(rec) + "\n")


def _fail(path: str, lineno: int, msg: str):
    raise TraceFormatError(f"{path}:{lineno}: {msg}")


def _parse_obj(path: str, lineno: int, line: str) -> dict:
    try:
        rec = json.loads(line)
    except json.JSONDecodeError as e:
        raise TraceFormatError(
            f"{path}:{lineno}: not valid JSON ({e})") from None
    if not isinstance(rec, dict):
        _fail(path, lineno, f"every line must be a JSON object, "
                            f"got {type(rec).__name__}")
    return rec


def _is_int(x) -> bool:
    return isinstance(x, int) and not isinstance(x, bool)


def load_trace_events(path: str) -> List[TraceEvent]:
    """Read and validate a repro-trace file (DESIGN §15) into TraceEvents.

    Every malformed or missing-field line raises `TraceFormatError` with
    the `path:line` it came from (never a bare KeyError). Headerless files
    are accepted as legacy version-1 length traces. Records whose
    timestamps are out of order are sorted with a warning."""
    with open(path) as f:
        lines = f.readlines()
    kind, start = "lengths", 0
    if lines:
        first = _parse_obj(path, 1, lines[0])
        if "schema" in first:
            if first["schema"] != TRACE_SCHEMA:
                _fail(path, 1, f"unknown schema {first['schema']!r} "
                               f"(want {TRACE_SCHEMA!r})")
            ver = first.get("version")
            if ver != TRACE_VERSION:
                _fail(path, 1, f"unsupported version {ver!r} (this reader "
                               f"understands version {TRACE_VERSION})")
            kind = first.get("kind", "lengths")
            if kind not in ("lengths", "tokens"):
                _fail(path, 1, f"unknown kind {kind!r} "
                               f"(want 'lengths' or 'tokens')")
            start = 1
    events: List[TraceEvent] = []
    seen_ids = set()
    for off, line in enumerate(lines[start:]):
        lineno = start + off + 1
        if not line.strip():
            continue
        rec = _parse_obj(path, lineno, line)
        t = rec.get("t")
        if isinstance(t, bool) or not isinstance(t, (int, float)) or t < 0:
            _fail(path, lineno, f"'t' must be a number >= 0, got {t!r}")
        lo = rec.get("l_out")
        if not _is_int(lo) or lo < 1:
            _fail(path, lineno, f"'l_out' must be an int >= 1, got {lo!r}")
        tokens = None
        if kind == "tokens":
            tokens = rec.get("tokens")
            if not isinstance(tokens, list) or not tokens \
                    or not all(_is_int(x) and x >= 0 for x in tokens):
                _fail(path, lineno,
                      "'tokens' must be a non-empty list of ints >= 0")
            li = len(tokens)
        else:
            li = rec.get("l_in")
            if not _is_int(li) or li < 1:
                _fail(path, lineno,
                      f"'l_in' must be an int >= 1, got {li!r}")
        rid = rec.get("id", len(events))
        if not _is_int(rid):
            _fail(path, lineno, f"'id' must be an int, got {rid!r}")
        if rid in seen_ids:
            _fail(path, lineno, f"duplicate id {rid}")
        pid = rec.get("parent_id")
        if pid is not None:
            if not _is_int(pid):
                _fail(path, lineno,
                      f"'parent_id' must be an int, got {pid!r}")
            if pid not in seen_ids:
                _fail(path, lineno, f"parent_id {pid} does not reference "
                                    f"an earlier request")
        seen_ids.add(rid)
        events.append(TraceEvent(
            t=float(t), l_out=lo, l_in=int(li),
            tokens=list(tokens) if tokens is not None else None,
            id=rid, parent_id=pid))
    if any(events[i].t < events[i - 1].t for i in range(1, len(events))):
        warnings.warn(f"{path}: arrival timestamps out of order; sorting",
                      stacklevel=2)
        events.sort(key=lambda e: e.t)
    return events


def load_trace(path: str):
    """Load a trace as plain tuples: Arrival for `lengths` traces,
    TokenArrival for `tokens` traces (the `save_trace` roundtrip twin).
    Use `load_trace_events` to keep ids and `parent_id` links."""
    evs = load_trace_events(path)
    if any(e.tokens is not None for e in evs):
        return [(e.t, list(e.tokens), e.l_out) for e in evs]
    return [(e.t, e.l_in, e.l_out) for e in evs]


def reference_trace(n: int, *, seed: int = 0, vocab_size: int = 1000,
                    base_rate: float = 4.0, burst_rate: float = 16.0,
                    period_s: float = 40.0, duty: float = 0.25,
                    n_system_prompts: int = 4, system_len: int = 32,
                    user_mean: float = 24.0, out_mean: float = 32.0,
                    length_cv: float = 0.6, p_followup: float = 0.5,
                    max_turns: int = 3,
                    turn_gap_s: float = 5.0) -> List[TraceEvent]:
    """Bundled synthetic reference trace (DESIGN §15): ShareGPT/Azure-LLM
    shaped without any external download, so CI can replay it.

    Conversation openers arrive via a Lewis–Shedler-thinned square-wave
    lambda(t); each prompt opens with one of `n_system_prompts` shared
    system prompts plus a lognormal user utterance; output lengths are
    lognormal; with probability `p_followup` (up to `max_turns`) the
    conversation re-arrives `parent_id`-linked, its prompt extending the
    previous turn's full transcript. Events are sorted by arrival time
    with ids equal to file order, so every parent precedes its children."""
    rng = random.Random(seed)
    pool = [[rng.randrange(vocab_size) for _ in range(system_len)]
            for _ in range(n_system_prompts)]
    max_rate = max(base_rate, burst_rate)

    def lam(t: float) -> float:
        return burst_rate if (t % period_s) / period_s < duty else base_rate

    def ln_len(mean: float) -> int:
        return max(1, int(rng.lognormvariate(*_lognorm(mean, length_cv))))

    def utterance():
        return [rng.randrange(vocab_size) for _ in range(ln_len(user_mean))]

    events: List[TraceEvent] = []
    t = 0.0
    while len(events) < n:
        # next conversation opener via thinning (same law as `bursty`)
        while True:
            t += rng.expovariate(max_rate)
            if rng.random() * max_rate <= lam(t):
                break
        prompt = list(rng.choice(pool)) + utterance()
        turn_t, parent = t, None
        for turn in range(max_turns):
            lo = ln_len(out_mean)
            ev = TraceEvent(t=turn_t, l_out=lo, l_in=len(prompt),
                            tokens=list(prompt), id=len(events),
                            parent_id=parent)
            events.append(ev)
            parent = ev.id
            if len(events) >= n or rng.random() >= p_followup:
                break
            prompt = prompt + [rng.randrange(vocab_size) for _ in range(lo)] \
                + utterance()
            turn_t += turn_gap_s * (1.0 + rng.random())
    # follow-up turns always land later than their parent, so a stable
    # sort keeps every parent ahead of its children; remap ids to file
    # order so the saved trace validates on load
    order = sorted(range(len(events)), key=lambda i: events[i].t)
    remap = {events[i].id: pos for pos, i in enumerate(order)}
    return [dataclasses.replace(
        events[i], id=pos,
        parent_id=None if events[i].parent_id is None
        else remap[events[i].parent_id]) for pos, i in enumerate(order)]


def feed_trace(sim: ServingSimulator,
               events: List[TraceEvent]) -> List[Request]:
    """Inject validated TraceEvents into a simulator: token-level records
    replay through the BlockManager exactly like `feed_tokens` (prefix
    sharing sees the real prompts), length-only records replay like
    `feed`. Same rid-offset discipline — safe to call repeatedly."""
    base = len(sim._all)
    new = [Request(rid=base + i, arrival_time=e.t,
                   prompt_tokens=list(e.tokens)
                   if e.tokens is not None else None,
                   prompt_len=e.prompt_len, true_output_len=e.l_out,
                   max_new_tokens=sim.serve.max_new_tokens)
           for i, e in enumerate(events)]
    sim.waiting.extend(new)
    sim.waiting.sort(key=lambda r: r.arrival_time)
    sim._all.extend(new)
    return new


def trace_prompts(events: List[TraceEvent], vocab_size: int,
                  seed: int = 0) -> List[Tuple[List[int], int]]:
    """Materialize engine-submittable (prompt_tokens, l_out) pairs from a
    trace: token records pass through with ids clamped into the model's
    vocab, length-only records get deterministic synthetic tokens."""
    rng = random.Random(seed)
    out: List[Tuple[List[int], int]] = []
    for e in events:
        if e.tokens is not None:
            toks = [tok % vocab_size for tok in e.tokens]
        else:
            toks = [rng.randrange(vocab_size)
                    for _ in range(max(1, e.l_in))]
        out.append((toks, e.l_out))
    return out
