"""Discrete-event simulator of a continuous-batching serving engine.

Runs the SAME controller stack (Telemetry -> Policy -> BlockManager
admission, DESIGN §1) as the real JAX engine, replacing the model step with
the CostModel time law and pre-sampled output lengths (DESIGN §7). This is
how the paper's GPU-scale tables (LLaMA-65B/70B, PanGu-7/38/135B) are
reproduced on CPU; the scheduling code under test is identical, byte for
byte.

Step semantics mirror the engine exactly, interval for interval (the
differential harness in `tests/test_differential.py` pins the parity):
  * non-fused mode: admission prefills each admitted request immediately
    (its first token comes from the prefill's final logits), then one
    decode iteration over the running batch.
  * PD-fusion mode (chunked prefill, DESIGN §6): each step packs
    `chunk_budget` prefill tokens across up to `n_prefill_lanes` concurrent
    prefills (the engine's lane semantics: sticky lanes, fifo/srf packer,
    optional per-lane chunk cap); finished lanes promote BEFORE the decode
    batch forms, so a promoted request decodes in its promotion interval.
  * preemption (DESIGN §11): newest victim first; per victim the cost-model
    crossover picks host-offload swap (blocks to the swap ledger, restored
    by the swapped-queue drain ahead of admission) vs recompute (KV
    discarded; the emitted count resets because the engine regenerates the
    victim's output from scratch).
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import random
from typing import Dict, List, Optional

from repro.config.base import ModelConfig, ServeConfig
from repro.core.batching import BatchDecision, Policy, bucketize, make_policy
from repro.core.lanes import lane_order, pack_chunks
from repro.core.memory_model import MemoryModel, kv_shard_factor
from repro.core.telemetry import Telemetry
from repro.serving.cost_model import CostModel
from repro.serving.kv_cache import (BlockManager, prefix_cache_supported,
                                    swap_supported)
from repro.serving.request import Request, RequestState


@dataclasses.dataclass
class LengthDist:
    """Request length sampler: lognormal-ish around the paper's workload
    means (paper §IV experimental setup; DESIGN §7)."""
    mean_in: float
    mean_out: float
    cv_in: float = 0.3          # coefficient of variation
    cv_out: float = 0.5
    fixed: bool = False         # PanGu rows: exactly 128/128

    def sample(self, rng: random.Random):
        if self.fixed:
            return int(self.mean_in), int(self.mean_out)
        li = max(1, int(rng.lognormvariate(*_lognorm(self.mean_in, self.cv_in))))
        lo = max(1, int(rng.lognormvariate(*_lognorm(self.mean_out, self.cv_out))))
        return li, lo


def _lognorm(mean: float, cv: float):
    import math
    sigma2 = math.log(1 + cv * cv)
    mu = math.log(mean) - sigma2 / 2
    return mu, math.sqrt(sigma2)


@dataclasses.dataclass
class SimResult:
    """Aggregate run metrics (the paper's Table I/II columns; DESIGN §7)."""
    total_tokens: int = 0
    duration_s: float = 0.0
    finished: int = 0
    admitted: int = 0               # successful admissions from `waiting`
    preemptions: int = 0            # evictions, recompute + swap-out alike
    oom_events: int = 0
    rejected: int = 0               # requests too large for the pool, dropped
    # two-tier swap (DESIGN §11)
    swap_outs: int = 0
    swap_ins: int = 0
    swap_out_bytes: int = 0
    swap_in_bytes: int = 0
    swapped_peak: int = 0           # peak concurrently offloaded requests
    swap_latency_s_mean: float = 0.0
    tbt_ms_mean: float = 0.0
    tbt_ms_p95: float = 0.0
    # prefix sharing (DESIGN §10): admission-time shared-prefix telemetry
    prefix_hit_tokens: int = 0
    prefix_query_tokens: int = 0
    prefix_hit_rate: float = 0.0
    cache_evictions: int = 0
    ttft_p90_s: float = 0.0         # time-to-first-token (queueing + prefill)
    ttft_mean_s: float = 0.0
    # TTFT attribution (DESIGN §6): queue wait vs prefill service means
    # (engine-summary key names — the differential harness compares by name)
    ttft_queue_s_mean: float = 0.0
    ttft_prefill_s_mean: float = 0.0
    prefill_lane_occupancy: float = 0.0  # mean busy-lane fraction, fused steps
    prefill_tokens: float = 0.0     # total prefill tokens packed (DESIGN §6)
    sla_attainment: float = 0.0     # fraction of decode steps within SLA
    # per-request goodput SLOs (DESIGN §15): requests meeting BOTH the
    # TTFT and mean-TBT SLAs, their token volume, and the attainment
    # fraction over finished + rejected (dropping a request counts
    # against attainment — rejection can never inflate it)
    sla_requests_met: int = 0
    goodput_tokens: int = 0
    request_sla_attainment: float = 0.0
    mean_batch: float = 0.0
    decode_steps: int = 0
    # host-vs-device interval split (DESIGN §14): the cost model's
    # host_overhead_ms share of each interval vs the device remainder
    step_host_s_mean: float = 0.0
    step_device_s_mean: float = 0.0
    # mesh-sharded pool (DESIGN §12) + end-of-run pool occupancy (§9/§10)
    model_shards: float = 1.0
    pool_tokens: float = 0.0
    cached_blocks: float = 0.0
    logical_used_tokens: float = 0.0
    physical_used_tokens: float = 0.0
    logical_used_bytes: float = 0.0
    physical_used_bytes: float = 0.0
    batch_trace: List[int] = dataclasses.field(default_factory=list)
    decisions: List[BatchDecision] = dataclasses.field(default_factory=list)

    @property
    def throughput_tok_s(self) -> float:
        return self.total_tokens / max(self.duration_s, 1e-9)

    @property
    def goodput_tok_s(self) -> float:
        """Throughput counting only SLA-met requests' tokens (DESIGN §15)."""
        return self.goodput_tokens / max(self.duration_s, 1e-9)


class ServingSimulator:
    """Discrete-event twin of `serving.engine.Engine` (DESIGN §7).

    `prefill_chunk` mirrors the engine's per-lane chunk cap in PD-fusion
    mode (0 = uncapped: a lane may take its whole remaining prompt within
    the interval budget)."""

    def __init__(self, cfg: ModelConfig, serve: ServeConfig, cost: CostModel,
                 lengths: LengthDist, seed: int = 0,
                 policy: Optional[Policy] = None, prefill_chunk: int = 0,
                 max_context: int = 0):
        self.cfg = cfg
        self.serve = serve
        self.cost = cost
        self.lengths = lengths
        self.rng = random.Random(seed)
        self.prefill_chunk = prefill_chunk
        # engine-mirrored per-request block-table width (DESIGN §9): with a
        # max_context the sim rejects prompts wider than the table exactly
        # like the engine; 0 = unbounded (the sim has no physical table)
        self.max_context = max_context
        self.max_blocks = -(-max_context // serve.block_size) \
            if max_context else 0
        self.n_lanes = max(1, serve.n_prefill_lanes)
        # PD-fusion lanes (DESIGN §6): sticky request-per-lane, same
        # semantics as the engine's spare physical rows
        self.lanes: List[Optional[Request]] = [None] * self.n_lanes

        # mesh-sharded serving (DESIGN §12): mirror the engine's chip-aware
        # pool — serve budgets are per-chip under a mesh and the effective
        # model-axis shard count scales the token capacity. The cost
        # model's derived budget already aggregates every chip, so it is
        # brought back to per-chip before MemoryModel re-scales it.
        self.model_shards = kv_shard_factor(cfg, serve.model_axis_size)
        pool_bytes = serve.hbm_budget_bytes \
            or cost.kv_pool_bytes() // self.model_shards
        self.mem = MemoryModel(cfg, pool_bytes, eps_m=serve.eps_m,
                               block_size=serve.block_size,
                               eta_tokens=serve.kv_pool_tokens,
                               model_shards=self.model_shards)
        eta = self.mem.eta
        if eta == 0:  # attention-free: cap by request state instead
            eta = self.mem.max_requests_state_only() * serve.block_size
        # prefix sharing (DESIGN §10): same family gate as the engine so
        # sim and engine hit rates stay comparable; the sim needs request
        # token content (feed_tokens / shared-prefix workloads) to match
        self.prefix = (serve.prefix_cache and prefix_cache_supported(cfg)
                       and self.mem.bytes_per_token != 0)
        # two-tier swap (DESIGN §11): the engine's exact gate — the engine
        # needs the paged pool to move physical blocks, so the sim honors
        # paged_kv too to keep the twins' behavior identical
        self.swap = (serve.swap_space_blocks > 0
                     and serve.preempt != "recompute" and serve.paged_kv
                     and swap_supported(cfg)
                     and self.mem.bytes_per_token != 0)
        self.blocks = BlockManager(eta, serve.block_size,
                                   prefix_cache=self.prefix,
                                   swap_space_blocks=serve.swap_space_blocks
                                   if self.swap else 0)
        self.tel = Telemetry(prior_mean_in=lengths.mean_in,
                             prior_mean_out=lengths.mean_out)
        self.policy = policy or make_policy(serve, self.mem)

        self.waiting: List[Request] = []
        self.running: List[Request] = []
        # fused-mode prefill backlog (admitted, chunk-prefilling; engine's
        # `prefilling` list)
        self.pending_prefill: List[Request] = []
        # offloaded requests awaiting swap-in (DESIGN §11); admission
        # drains this queue before `waiting`, exactly like the engine
        self.swapped: List[Request] = []
        self._all: List[Request] = []
        self.now = 0.0
        self.res = SimResult()
        self._tbts: List[float] = []
        self._swap_waits: List[float] = []
        self._sla_ok = 0
        self._sla_steps = 0
        # async dispatch-ahead mirror (DESIGN §14): telemetry feeds lag
        # behind scheduling by up to overlap_depth dispatched intervals,
        # exactly like the engine's retirement pipeline; the clock charges
        # max(host, device) per interval instead of host + device
        self.overlap_depth = max(0, int(serve.overlap_depth))
        self._feed_lag: "collections.deque[list]" = collections.deque()
        self._feeds: list = []      # current interval's deferred feeds
        self._host_s: List[float] = []
        self._dev_s: List[float] = []

    # -- workload -------------------------------------------------------------
    def add_requests(self, n: int, arrival_rate: float = 0.0):
        """arrival_rate == 0 => infinite backlog (all at t=0, paper Table I).

        Safe to call repeatedly (and to mix with workload.feed/_tokens/
        _trace): rids offset past the existing population, `_all` grows by
        only the new requests."""
        base = len(self._all)
        t = 0.0
        new = []
        for i in range(n):
            li, lo = self.lengths.sample(self.rng)
            new.append(Request(
                rid=base + i, arrival_time=t, prompt_len=li,
                true_output_len=lo,
                max_new_tokens=self.serve.max_new_tokens))
            if arrival_rate > 0:
                t += self.rng.expovariate(arrival_rate)
        self.waiting.extend(new)
        self.waiting.sort(key=lambda r: r.arrival_time)
        self._all.extend(new)

    # -- scheduling interval ----------------------------------------------------
    def _snapshot(self):
        arrived = [r for r in self.waiting if r.arrival_time <= self.now]
        # engine-mirrored N^p: un-admitted arrivals + the fused prefill
        # backlog (engine counts waiting + prefilling)
        return self.tel.snapshot(
            now=self.now,
            n_prefill=len(arrived) + len(self.pending_prefill),
            n_decode=len(self.running),
            free_tokens=self.blocks.free_tokens,
            logical_used_tokens=self.blocks.logical_used_tokens,
            physical_used_tokens=self.blocks.physical_used_tokens,
            swapped_tokens=self.blocks.swapped_tokens)

    def _admit(self, decision: BatchDecision):
        """Admission control: fill up to max_batch respecting the block pool."""
        # engine-mirrored floor-bucket guard: rounding UP to the smallest
        # compiled bucket must not admit past the controller's decision
        cap = bucketize(decision.max_batch, self.serve.batch_buckets) \
            if self.serve.batch_buckets else decision.max_batch
        cap = min(cap, decision.max_batch)
        # swap-in drain (DESIGN §11, engine-mirrored): offloaded requests
        # re-enter before any new admission, and while any remain the
        # waiting queue is held back
        while self.swapped \
                and len(self.running) + len(self.pending_prefill) < cap:
            if not self._swap_in_next():
                self.res.oom_events += 1
                break
        admitted = []
        if self.swapped:
            return admitted
        for r in list(self.waiting):
            # engine-mirrored cap: running + prefill backlog + this batch
            if len(self.running) + len(self.pending_prefill) \
                    + len(admitted) >= cap:
                break
            if r.arrival_time > self.now:
                break
            need = r.context_len + 1  # context covers recompute re-prefill
            if self.mem.bytes_per_token == 0:
                need = self.serve.block_size  # state-only families
            # prefix sharing (DESIGN §10): engine-mirrored — map shared
            # full prompt blocks first, gate on the suffix, roll back on
            # refusal so hit rates stay engine-comparable
            cached = 0
            if self.prefix and r.prompt_tokens:
                cached = self.blocks.acquire_prefix(r.rid, r.prompt_tokens)
            have = len(self.blocks.tables.get(r.rid, ()))
            nb = self.blocks.blocks_needed(0, need, r.rid)
            mb = self.max_blocks - have if self.max_blocks else 0
            # shared engine/sim gate (DESIGN §7): vLLM 1% watermark +
            # unservable rejection live in BlockManager.admission_verdict
            verdict = "reject" if self.max_blocks and mb <= 0 and nb > 0 \
                else self.blocks.admission_verdict(nb, mb)
            if verdict != "admit":
                if cached:
                    self.blocks.free(r.rid)
                if verdict == "reject":
                    self.waiting.remove(r)
                    r.state = RequestState.FINISHED
                    r.rejected = True
                    # goodput verdict (DESIGN §15): a dropped request
                    # counts against attainment, never for it
                    r.stamp_sla(self.serve.ttft_sla_s,
                                self.serve.tbt_sla_ms)
                    self.res.rejected += 1
                    continue
                self.res.oom_events += 1
                break
            self.blocks.allocate(r.rid, 0, need)
            if self.prefix:
                self.blocks.note_prefix_query(r.prompt_len, cached)
            r.cached_prefix_len = cached
            self.res.admitted += 1
            admitted.append(r)
        for r in admitted:
            self.waiting.remove(r)
            r.state = RequestState.PREFILLING
            r.prefill_pos = r.cached_prefix_len
        return admitted

    def _preempt_if_needed(self):
        """On pool exhaustion mid-decode, evict newest requests; per victim
        the DESIGN §11 crossover picks host-offload swap vs recompute."""
        if self.mem.bytes_per_token == 0:
            return  # constant per-request state: decode never grows it
        while self.running:
            need = sum(self.blocks.blocks_needed(r.context_len, 1, r.rid)
                       for r in self.running)
            if need <= self.blocks.free_blocks:
                return
            victim = self.running[-1]  # newest first in BOTH modes (vLLM)
            if self._should_swap(victim):
                self._swap_out(victim)
            else:
                self._recompute_evict(victim)

    def _recompute_evict(self, victim: Request):
        """Recompute preemption: discard the victim's KV; it re-prefills
        its prompt from scratch and regenerates its output (the engine
        clears `output_tokens`; greedy decoding makes the regenerated
        tokens identical)."""
        self.running.remove(victim)
        self.blocks.free(victim.rid)
        victim.state = RequestState.WAITING
        victim.prefill_pos = 0
        # recompute re-probes the prefix index at re-admission (§10)
        victim.cached_prefix_len = 0
        # engine-mirrored: re-attribute TTFT on the recompute pass
        victim.prefill_start_time = -1.0
        victim.sim_reset_output()
        self.waiting.insert(0, victim)
        self.res.preemptions += 1

    def _should_swap(self, r: Request) -> bool:
        """Engine-mirrored per-victim choice (DESIGN §11): host space +
        no shared blocks + re-admittable, then the cost-model crossover
        (preempt="swap" forces swap whenever possible)."""
        if not self.swap \
                or not self.blocks.can_swap_out(r.rid, self.max_blocks):
            return False
        if self.serve.preempt == "swap":
            return True
        return self.cost.swap_beats_recompute(
            len(self.blocks.tables[r.rid]), self.serve.block_size,
            r.context_len)

    def _swap_out(self, r: Request):
        """Offload the victim to the host pool: the PCIe transfer time
        lands on the sim clock, the blocks move to the swap ledger."""
        nb = len(self.blocks.tables[r.rid])
        self.blocks.swap_out(r.rid)
        self.now += self.cost.pcie_s(nb, self.serve.block_size)
        self.res.swap_outs += 1
        self.res.preemptions += 1
        self.res.swap_out_bytes += self.mem.blocks_to_bytes(nb)
        r.state = RequestState.SWAPPED
        r.swap_out_time = self.now
        self.running.remove(r)
        self.swapped.append(r)

    def _swap_in_next(self) -> bool:
        """Restore the oldest swapped request (FIFO), gated by the same
        watermark verdict as admission; False when the pool can't take it."""
        r = self.swapped[0]
        nb = len(self.blocks.swapped_tables[r.rid])
        if self.blocks.admission_verdict(nb, self.max_blocks) != "admit":
            return False
        self.blocks.swap_in(r.rid)
        self.now += self.cost.pcie_s(nb, self.serve.block_size)
        self.res.swap_ins += 1
        self.res.swap_in_bytes += self.mem.blocks_to_bytes(nb)
        if r.swap_out_time >= 0:
            wait = self.now - r.swap_out_time
            r.swapped_s += wait
            r.n_swaps += 1
            r.swap_out_time = -1.0
            self._swap_waits.append(wait)
        r.state = RequestState.RUNNING
        self.swapped.pop(0)
        self.running.append(r)
        return True

    # -- async dispatch-ahead mirror (DESIGN §14) ------------------------------
    def _tel_feed(self, fn, *args):
        """Park a telemetry feed behind the interval's retirement: the
        engine applies an interval's TBT/TTFT/throughput/completion feeds
        only when its device step retires, up to overlap_depth intervals
        later — the sim mirrors the same staleness so the twins' policies
        read identical snapshots. Args are evaluated NOW (dispatch-time
        values), only the application is deferred."""
        self._feeds.append((fn, args))

    def _retire_feeds(self, dispatched: bool):
        """End-of-interval retirement mirror: queue the interval's feed
        list iff it dispatched device work (the engine only pushes a
        retirement record then), and retire down to the pipeline depth.
        Depth 0 flushes the interval's own feeds before the next snapshot
        — byte-identical to the synchronous loop."""
        if dispatched:
            self._feed_lag.append(self._feeds)
            self._feeds = []
        while len(self._feed_lag) > self.overlap_depth:
            for fn, args in self._feed_lag.popleft():
                fn(*args)

    def _advance_clock(self, dt: float):
        """Advance the sim clock by one interval's tau. Under overlap the
        host share (admission, lane packing, block-table edits) runs
        concurrently with the in-flight device step, so the interval
        costs max(host, device) instead of host + device — the pipeline's
        whole throughput win (DESIGN §14)."""
        host, dev = self.cost.split_host_device(dt)
        self._host_s.append(host)
        self._dev_s.append(dev)
        self.now += max(host, dev) if self.overlap_depth else dt

    # -- steps -------------------------------------------------------------------
    def _prefill_step(self, reqs: List[Request]):
        # context_len covers recompute-after-preemption (prompt + kept
        # output); a shared prefix is already resident, so only suffix
        # tokens are charged to the prefill cost — attention still reads
        # the full context (DESIGN §10)
        toks = sum(r.context_len - r.cached_prefix_len for r in reqs)
        ctx = sum(r.context_len for r in reqs) / max(len(reqs), 1)
        for r in reqs:
            if r.prefill_start_time < 0:
                r.prefill_start_time = self.now
        dt = self.cost.tau_step_s(0, 0.0, prefill_tokens=toks, prefill_ctx=ctx)
        self._advance_clock(dt)
        for r in reqs:
            r.state = RequestState.RUNNING
            r.first_token_time = self.now
            if self.prefix and r.prompt_tokens:
                self.blocks.commit_prefill(r.rid, r.prompt_tokens,
                                           r.prompt_len)
            self._tel_feed(self.tel.on_first_token,
                           r.prefill_start_time - r.arrival_time,
                           self.now - r.prefill_start_time)
            # the engine samples the first output token from the prefill's
            # final logits — mirror the emission so step counts line up
            r.sim_emit_token()
            self.running.append(r)

    # -- PD-fusion lane packer (shared with the engine, DESIGN §6) -------------
    def _fill_lanes(self, pending: List[Request]):
        queued = [(None, r) for r in pending if r.lane < 0]
        if not queued:
            return
        queued = lane_order(self.serve.prefill_pack, queued)
        for j in range(self.n_lanes):
            if self.lanes[j] is not None:
                continue
            if not queued:
                break
            _, r = queued.pop(0)
            r.lane = j
            self.lanes[j] = r

    def _decode_step(self, fused_prefill: List[Request], chunk_budget: int):
        pf_tokens = 0
        promoted: List[Request] = []
        # zero budget skips lane filling too (the engine's _advance_prefill
        # returns before assigning lanes) so lane assignment order cannot
        # drift between the twins across zero-budget intervals
        if fused_prefill and chunk_budget > 0:
            self._fill_lanes(fused_prefill)
            plan = pack_chunks(self.serve.prefill_pack, self.lanes,
                               chunk_budget, self.prefill_chunk)
            lane_tokens: Dict[int, int] = {}
            for j, r, take in plan:
                if r.prefill_start_time < 0:
                    r.prefill_start_time = self.now
                r.prefill_pos += take
                if self.prefix and r.prompt_tokens:
                    self.blocks.commit_prefill(r.rid, r.prompt_tokens,
                                               r.prefill_pos)
                lane_tokens[j] = take
            pf_tokens = sum(lane_tokens.values())
            if lane_tokens:
                self._tel_feed(self.tel.on_prefill_interval, lane_tokens,
                               self.n_lanes)
            # finished lanes promote BEFORE the decode batch forms
            # (lane-index order: deterministic, matches the engine) — a
            # promoted request decodes in its promotion interval
            for j in range(self.n_lanes):
                r = self.lanes[j]
                if r is None or r.prefill_pos < r.prompt_len:
                    continue
                self.lanes[j] = None
                r.lane = -1
                r.state = RequestState.RUNNING
                promoted.append(r)
                self.running.append(r)
                fused_prefill.remove(r)
        b = len(self.running)
        mean_ctx = sum(r.context_len for r in self.running) / max(b, 1)
        dt = self.cost.tau_step_s(b, mean_ctx, prefill_tokens=pf_tokens,
                                  prefill_ctx=mean_ctx)
        self._advance_clock(dt)
        tbt_ms = dt * 1e3
        # a promoted request's first token comes from the final prefill
        # chunk's logits (the engine appends it at promotion), then it
        # joins the decode emission below — two tokens in the promotion
        # interval, exactly like the engine
        for r in promoted:
            r.first_token_time = self.now
            self._tel_feed(self.tel.on_first_token,
                           r.prefill_start_time - r.arrival_time,
                           self.now - r.prefill_start_time)
            r.sim_emit_token()
        if b:
            self._tel_feed(self.tel.on_decode_step, tbt_ms, b)
            self._tbts.append(tbt_ms)
            self.res.decode_steps += 1
            self._sla_steps += 1
            if self.serve.d_sla_ms <= 0 or tbt_ms <= self.serve.d_sla_ms \
                    + self.serve.eps_d_ms:
                self._sla_ok += 1
        # token emission + growth + completion, engine-mirrored: grow the
        # KV for the NEXT step's write, emit, finish-check; finished
        # requests free in reverse order; failed grows preempt (recompute)
        # after finish processing instead of silently drifting. State-only
        # families (bytes_per_token == 0) hold constant per-request state —
        # growing them would drain free_tokens linearly (phantom usage).
        self.res.total_tokens += b
        finished: List[Request] = []
        grow_failed: List[Request] = []
        for r in self.running:
            grew = True
            if self.mem.bytes_per_token != 0:
                grew = self.blocks.allocate(r.rid, r.context_len, 1)
            r.sim_emit_token()
            if r.done or (self.max_context
                          and r.context_len >= self.max_context - 1):
                finished.append(r)
            elif not grew:
                grow_failed.append(r)
        for r in reversed(finished):
            r.state = RequestState.FINISHED
            r.finish_time = self.now
            # goodput verdict (DESIGN §15): the sim's mirror of the
            # engine's retirement stamping — timestamps are final here
            if r.stamp_sla(self.serve.ttft_sla_s, self.serve.tbt_sla_ms):
                self.res.sla_requests_met += 1
                self.res.goodput_tokens += r.output_len
            self._tel_feed(self.tel.on_completion, r.output_len)
            self.blocks.free(r.rid)
            self.running.remove(r)
            self.res.finished += 1
        for r in grow_failed:
            if r in self.running:
                self._recompute_evict(r)
        self.res.batch_trace.append(b)
        # the engine only queues a retirement record when a graph was
        # dispatched — mirror that so the feed pipeline's cadence matches
        return pf_tokens > 0 or b > 0

    # -- main loop -----------------------------------------------------------------
    def run(self, max_steps: int = 200_000) -> SimResult:
        for r in self.waiting:
            self.tel.on_arrival(r.arrival_time, r.prompt_len)
        pending_prefill = self.pending_prefill
        steps = 0
        while (self.waiting or self.running or pending_prefill
               or self.swapped) and steps < max_steps:
            steps += 1
            # idle-advance to next arrival if nothing to do
            if not self.running and not pending_prefill \
                    and not self.swapped and self.waiting \
                    and self.waiting[0].arrival_time > self.now:
                self.now = self.waiting[0].arrival_time
            tel = self._snapshot()
            decision = self.policy.step(tel)
            self.res.decisions.append(decision)
            admitted = self._admit(decision)
            if self.serve.chunked_prefill:
                pending_prefill.extend(admitted)
                self._preempt_if_needed()
                budget = decision.chunk_budget \
                    or self.serve.chunk_budget_tokens
                if budget <= 0 and pending_prefill and not self.running:
                    # engine-mirrored livelock guard: no decodes and no
                    # budget would spin no-op steps forever
                    budget = self.prefill_chunk \
                        or pending_prefill[0].prompt_len
                dispatched = self._decode_step(pending_prefill, budget)
            else:
                # engine order: admitted requests prefill immediately
                # (inside the engine's admission loop), THEN the pool
                # pressure check runs — just-prefilled requests are
                # preemption candidates like any other
                dispatched = bool(admitted)
                if admitted:
                    self._prefill_step(admitted)
                self._preempt_if_needed()
                if self.running:
                    dispatched = self._decode_step([], 0) or dispatched
            # no physical pos rows to clear in the sim — drain the
            # eviction queue so it cannot grow for the run's lifetime
            self.blocks.take_released()
            self._retire_feeds(dispatched)
        # pipeline drain, engine-mirrored: the engine's final idle step()
        # retires every in-flight interval before reporting idle
        while self._feed_lag:
            for fn, args in self._feed_lag.popleft():
                fn(*args)
        self.res.duration_s = self.now
        ttfts = sorted(r.first_token_time - r.arrival_time
                       for r in self._all if r.first_token_time >= 0)
        if ttfts:
            self.res.ttft_p90_s = ttfts[int(0.9 * (len(ttfts) - 1))]
            self.res.ttft_mean_s = sum(ttfts) / len(ttfts)
        served = [r for r in self._all
                  if r.first_token_time >= 0 and r.prefill_start_time >= 0]
        if served:
            self.res.ttft_queue_s_mean = sum(
                r.prefill_start_time - r.arrival_time for r in served) \
                / len(served)
            self.res.ttft_prefill_s_mean = sum(
                r.first_token_time - r.prefill_start_time for r in served) \
                / len(served)
        if self.tel.lane_occ:
            self.res.prefill_lane_occupancy = \
                sum(self.tel.lane_occ) / len(self.tel.lane_occ)
        if self._tbts:
            s = sorted(self._tbts)
            self.res.tbt_ms_mean = sum(s) / len(s)
            self.res.tbt_ms_p95 = s[int(0.95 * (len(s) - 1))]
        if self._sla_steps:
            self.res.sla_attainment = self._sla_ok / self._sla_steps
        self.res.request_sla_attainment = self.res.sla_requests_met \
            / max(self.res.finished + self.res.rejected, 1)
        if self._host_s:
            self.res.step_host_s_mean = sum(self._host_s) / len(self._host_s)
            self.res.step_device_s_mean = sum(self._dev_s) / len(self._dev_s)
        if self.res.batch_trace:
            self.res.mean_batch = sum(self.res.batch_trace) / len(self.res.batch_trace)
        self.res.prefix_hit_tokens = self.blocks.prefix_hit_tokens
        self.res.prefix_query_tokens = self.blocks.prefix_query_tokens
        self.res.prefix_hit_rate = self.blocks.prefix_hit_rate
        self.res.cache_evictions = self.blocks.cache_evictions
        self.res.swapped_peak = self.blocks.swapped_peak
        if self._swap_waits:
            self.res.swap_latency_s_mean = \
                sum(self._swap_waits) / len(self._swap_waits)
        # engine-summary twins (counter-parity): shard/pool geometry,
        # prefill volume and end-of-run pool occupancy
        self.res.model_shards = float(self.model_shards)
        self.res.pool_tokens = float(self.mem.eta)
        self.res.prefill_tokens = float(self.tel.prefill_tokens_total)
        self.res.cached_blocks = float(self.blocks.cached_blocks)
        self.res.logical_used_tokens = float(self.blocks.logical_used_tokens)
        self.res.physical_used_tokens = float(self.blocks.physical_used_tokens)
        self.res.logical_used_bytes = float(self.mem.tokens_to_bytes(
            self.blocks.logical_used_tokens))
        self.res.physical_used_bytes = float(self.mem.tokens_to_bytes(
            self.blocks.physical_used_tokens))
        return self.res
