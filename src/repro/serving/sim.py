"""Discrete-event simulator of a continuous-batching serving engine.

Runs the SAME controller stack (Telemetry -> Policy -> BlockManager
admission, DESIGN §1) as the real JAX engine, replacing the model step with
the CostModel time law and pre-sampled output lengths (DESIGN §7). This is
how the paper's GPU-scale tables (LLaMA-65B/70B, PanGu-7/38/135B) are
reproduced on CPU; the scheduling code under test is identical, byte for
byte.

Step semantics mirror vLLM 0.x (the paper's substrate):
  * non-fused mode: a step is EITHER a prefill batch (when the policy admits
    waiting requests and prefill work exists) OR one decode iteration.
  * PD-fusion mode (chunked prefill, DESIGN §6): each step packs
    `chunk_budget` prefill tokens across up to `n_prefill_lanes` concurrent
    prefills (the engine's lane semantics: sticky lanes, fifo/srf packer,
    optional per-lane chunk cap) alongside all running decodes.
"""
from __future__ import annotations

import dataclasses
import heapq
import random
from typing import Dict, List, Optional

from repro.config.base import ModelConfig, ServeConfig
from repro.core.batching import BatchDecision, Policy, bucketize, make_policy
from repro.core.lanes import lane_order, pack_chunks
from repro.core.memory_model import MemoryModel
from repro.core.telemetry import Telemetry
from repro.serving.cost_model import CostModel
from repro.serving.kv_cache import BlockManager, prefix_cache_supported
from repro.serving.request import Request, RequestState


@dataclasses.dataclass
class LengthDist:
    """Request length sampler: lognormal-ish around the paper's workload
    means (paper §IV experimental setup; DESIGN §7)."""
    mean_in: float
    mean_out: float
    cv_in: float = 0.3          # coefficient of variation
    cv_out: float = 0.5
    fixed: bool = False         # PanGu rows: exactly 128/128

    def sample(self, rng: random.Random):
        if self.fixed:
            return int(self.mean_in), int(self.mean_out)
        li = max(1, int(rng.lognormvariate(*_lognorm(self.mean_in, self.cv_in))))
        lo = max(1, int(rng.lognormvariate(*_lognorm(self.mean_out, self.cv_out))))
        return li, lo


def _lognorm(mean: float, cv: float):
    import math
    sigma2 = math.log(1 + cv * cv)
    mu = math.log(mean) - sigma2 / 2
    return mu, math.sqrt(sigma2)


@dataclasses.dataclass
class SimResult:
    """Aggregate run metrics (the paper's Table I/II columns; DESIGN §7)."""
    total_tokens: int = 0
    duration_s: float = 0.0
    finished: int = 0
    preemptions: int = 0
    oom_events: int = 0
    rejected: int = 0               # requests too large for the pool, dropped
    tbt_ms_mean: float = 0.0
    tbt_ms_p95: float = 0.0
    # prefix sharing (DESIGN §10): admission-time shared-prefix telemetry
    prefix_hit_tokens: int = 0
    prefix_query_tokens: int = 0
    prefix_hit_rate: float = 0.0
    cache_evictions: int = 0
    ttft_p90_s: float = 0.0         # time-to-first-token (queueing + prefill)
    ttft_mean_s: float = 0.0
    # TTFT attribution (DESIGN §6): queue wait vs prefill service means
    ttft_queue_mean_s: float = 0.0
    ttft_prefill_mean_s: float = 0.0
    prefill_lane_occupancy: float = 0.0  # mean busy-lane fraction, fused steps
    sla_attainment: float = 0.0     # fraction of decode steps within SLA
    mean_batch: float = 0.0
    batch_trace: List[int] = dataclasses.field(default_factory=list)
    decisions: List[BatchDecision] = dataclasses.field(default_factory=list)

    @property
    def throughput(self) -> float:
        return self.total_tokens / max(self.duration_s, 1e-9)


class ServingSimulator:
    """Discrete-event twin of `serving.engine.Engine` (DESIGN §7).

    `prefill_chunk` mirrors the engine's per-lane chunk cap in PD-fusion
    mode (0 = uncapped: a lane may take its whole remaining prompt within
    the interval budget)."""

    def __init__(self, cfg: ModelConfig, serve: ServeConfig, cost: CostModel,
                 lengths: LengthDist, seed: int = 0,
                 policy: Optional[Policy] = None, prefill_chunk: int = 0,
                 max_context: int = 0):
        self.cfg = cfg
        self.serve = serve
        self.cost = cost
        self.lengths = lengths
        self.rng = random.Random(seed)
        self.prefill_chunk = prefill_chunk
        # engine-mirrored per-request block-table width (DESIGN §9): with a
        # max_context the sim rejects prompts wider than the table exactly
        # like the engine; 0 = unbounded (the sim has no physical table)
        self.max_blocks = -(-max_context // serve.block_size) \
            if max_context else 0
        self.n_lanes = max(1, serve.n_prefill_lanes)
        # PD-fusion lanes (DESIGN §6): sticky request-per-lane, same
        # semantics as the engine's spare physical rows
        self.lanes: List[Optional[Request]] = [None] * self.n_lanes

        pool_bytes = serve.hbm_budget_bytes or cost.kv_pool_bytes()
        self.mem = MemoryModel(cfg, pool_bytes, eps_m=serve.eps_m,
                               block_size=serve.block_size,
                               eta_tokens=serve.kv_pool_tokens)
        eta = serve.kv_pool_tokens or self.mem.eta
        if eta == 0:  # attention-free: cap by request state instead
            eta = self.mem.max_requests_state_only() * serve.block_size
        # prefix sharing (DESIGN §10): same family gate as the engine so
        # sim and engine hit rates stay comparable; the sim needs request
        # token content (feed_tokens / shared-prefix workloads) to match
        self.prefix = (serve.prefix_cache and prefix_cache_supported(cfg)
                       and self.mem.bytes_per_token != 0)
        self.blocks = BlockManager(eta, serve.block_size,
                                   prefix_cache=self.prefix)
        self.tel = Telemetry(prior_mean_in=lengths.mean_in,
                             prior_mean_out=lengths.mean_out)
        self.policy = policy or make_policy(serve, self.mem)

        self.waiting: List[Request] = []
        self.running: List[Request] = []
        # fused-mode prefill backlog (admitted, chunk-prefilling; engine's
        # `prefilling` list)
        self.pending_prefill: List[Request] = []
        self._all: List[Request] = []
        self.now = 0.0
        self.res = SimResult()
        self._tbts: List[float] = []
        self._sla_ok = 0
        self._sla_steps = 0

    # -- workload -------------------------------------------------------------
    def add_requests(self, n: int, arrival_rate: float = 0.0):
        """arrival_rate == 0 => infinite backlog (all at t=0, paper Table I)."""
        t = 0.0
        for i in range(n):
            li, lo = self.lengths.sample(self.rng)
            self.waiting.append(Request(
                rid=i, arrival_time=t, prompt_len=li, true_output_len=lo,
                max_new_tokens=self.serve.max_new_tokens))
            if arrival_rate > 0:
                t += self.rng.expovariate(arrival_rate)
        self.waiting.sort(key=lambda r: r.arrival_time)
        self._all.extend(self.waiting)

    # -- scheduling interval ----------------------------------------------------
    def _snapshot(self):
        arrived = [r for r in self.waiting if r.arrival_time <= self.now]
        # engine-mirrored N^p: un-admitted arrivals + the fused prefill
        # backlog (engine counts waiting + prefilling)
        return self.tel.snapshot(
            now=self.now,
            n_prefill=len(arrived) + len(self.pending_prefill),
            n_decode=len(self.running),
            free_tokens=self.blocks.free_tokens,
            logical_used_tokens=self.blocks.logical_used_tokens,
            physical_used_tokens=self.blocks.physical_used_tokens)

    def _admit(self, decision: BatchDecision):
        """Admission control: fill up to max_batch respecting the block pool."""
        # engine-mirrored floor-bucket guard: rounding UP to the smallest
        # compiled bucket must not admit past the controller's decision
        cap = bucketize(decision.max_batch, self.serve.batch_buckets) \
            if self.serve.batch_buckets else decision.max_batch
        cap = min(cap, decision.max_batch)
        admitted = []
        for r in list(self.waiting):
            # engine-mirrored cap: running + prefill backlog + this batch
            if len(self.running) + len(self.pending_prefill) \
                    + len(admitted) >= cap:
                break
            if r.arrival_time > self.now:
                break
            need = r.context_len + 1  # context covers recompute re-prefill
            if self.mem.bytes_per_token == 0:
                need = self.serve.block_size  # state-only families
            # prefix sharing (DESIGN §10): engine-mirrored — map shared
            # full prompt blocks first, gate on the suffix, roll back on
            # refusal so hit rates stay engine-comparable
            cached = 0
            if self.prefix and r.prompt_tokens:
                cached = self.blocks.acquire_prefix(r.rid, r.prompt_tokens)
            have = len(self.blocks.tables.get(r.rid, ()))
            nb = self.blocks.blocks_needed(0, need, r.rid)
            mb = self.max_blocks - have if self.max_blocks else 0
            # shared engine/sim gate (DESIGN §7): vLLM 1% watermark +
            # unservable rejection live in BlockManager.admission_verdict
            verdict = "reject" if self.max_blocks and mb <= 0 and nb > 0 \
                else self.blocks.admission_verdict(nb, mb)
            if verdict != "admit":
                if cached:
                    self.blocks.free(r.rid)
                if verdict == "reject":
                    self.waiting.remove(r)
                    r.state = RequestState.FINISHED
                    r.rejected = True
                    self.res.rejected += 1
                    continue
                self.res.oom_events += 1
                break
            self.blocks.allocate(r.rid, 0, need)
            if self.prefix:
                self.blocks.note_prefix_query(r.prompt_len, cached)
            r.cached_prefix_len = cached
            admitted.append(r)
        for r in admitted:
            self.waiting.remove(r)
            r.state = RequestState.PREFILLING
            r.prefill_pos = r.cached_prefix_len
        return admitted

    def _preempt_if_needed(self):
        """On pool exhaustion mid-decode, evict newest requests (recompute)."""
        if self.mem.bytes_per_token == 0:
            return  # constant per-request state: decode never grows it
        while self.running:
            grow = [r for r in self.running
                    if self.blocks.blocks_needed(r.context_len, 1, r.rid) > 0]
            need = sum(self.blocks.blocks_needed(r.context_len, 1, r.rid)
                       for r in grow)
            if need <= self.blocks.free_blocks:
                return
            victim = self.running.pop()  # newest (vLLM recompute policy)
            self.blocks.free(victim.rid)
            victim.state = RequestState.WAITING
            victim.prefill_pos = 0
            # recompute re-probes the prefix index at re-admission (§10)
            victim.cached_prefix_len = 0
            # engine-mirrored: re-attribute TTFT on the recompute pass
            victim.prefill_start_time = -1.0
            # vLLM recompute: generated tokens are REPLAYED as prefill (they
            # are kept, not regenerated) — context_len stays, only the KV is
            # rebuilt. The re-prefill cost lands in _prefill_step via
            # context_len.
            self.waiting.insert(0, victim)
            self.res.preemptions += 1

    # -- steps -------------------------------------------------------------------
    def _prefill_step(self, reqs: List[Request]):
        # context_len covers recompute-after-preemption (prompt + kept
        # output); a shared prefix is already resident, so only suffix
        # tokens are charged to the prefill cost — attention still reads
        # the full context (DESIGN §10)
        toks = sum(r.context_len - r.cached_prefix_len for r in reqs)
        ctx = sum(r.context_len for r in reqs) / max(len(reqs), 1)
        for r in reqs:
            if r.prefill_start_time < 0:
                r.prefill_start_time = self.now
        dt = self.cost.tau_step_s(0, 0.0, prefill_tokens=toks, prefill_ctx=ctx)
        self.now += dt
        for r in reqs:
            r.state = RequestState.RUNNING
            r.first_token_time = self.now
            if self.prefix and r.prompt_tokens:
                self.blocks.commit_prefill(r.rid, r.prompt_tokens,
                                           r.prompt_len)
            self.tel.on_first_token(r.prefill_start_time - r.arrival_time,
                                    self.now - r.prefill_start_time)
            self.running.append(r)

    # -- PD-fusion lane packer (shared with the engine, DESIGN §6) -------------
    def _fill_lanes(self, pending: List[Request]):
        queued = [(None, r) for r in pending if r.lane < 0]
        if not queued:
            return
        queued = lane_order(self.serve.prefill_pack, queued)
        for j in range(self.n_lanes):
            if self.lanes[j] is not None:
                continue
            if not queued:
                break
            _, r = queued.pop(0)
            r.lane = j
            self.lanes[j] = r

    def _decode_step(self, fused_prefill: List[Request], chunk_budget: int):
        b = len(self.running)
        mean_ctx = sum(r.context_len for r in self.running) / max(b, 1)
        # grow KV by one token per running request. State-only families
        # (bytes_per_token == 0) hold constant per-request state — growing
        # them would drain free_tokens linearly (phantom usage, spurious
        # preemptions). A failed grow is an OOM event, not silent drift.
        if self.mem.bytes_per_token != 0:
            for r in self.running:
                if not self.blocks.allocate(r.rid, r.context_len, 1):
                    self.res.oom_events += 1
        pf_tokens = 0
        if fused_prefill:
            self._fill_lanes(fused_prefill)
            plan = pack_chunks(self.serve.prefill_pack, self.lanes,
                               chunk_budget, self.prefill_chunk)
            lane_tokens: Dict[int, int] = {}
            for j, r, take in plan:
                if r.prefill_start_time < 0:
                    r.prefill_start_time = self.now
                r.prefill_pos += take
                if self.prefix and r.prompt_tokens:
                    self.blocks.commit_prefill(r.rid, r.prompt_tokens,
                                               r.prefill_pos)
                lane_tokens[j] = take
            pf_tokens = sum(lane_tokens.values())
            if lane_tokens:
                self.tel.on_prefill_interval(lane_tokens, self.n_lanes)
        dt = self.cost.tau_step_s(b, mean_ctx, prefill_tokens=pf_tokens,
                                  prefill_ctx=mean_ctx)
        self.now += dt
        tbt_ms = dt * 1e3
        if b:
            self.tel.on_decode_step(tbt_ms, b)
            self._tbts.append(tbt_ms)
            self._sla_steps += 1
            if self.serve.d_sla_ms <= 0 or tbt_ms <= self.serve.d_sla_ms \
                    + self.serve.eps_d_ms:
                self._sla_ok += 1
        # finished lanes promote to running (lane-index order: deterministic,
        # matches the engine)
        for j in range(self.n_lanes):
            r = self.lanes[j]
            if r is None or r.prefill_pos < r.prompt_len:
                continue
            self.lanes[j] = None
            r.lane = -1
            r.state = RequestState.RUNNING
            r.first_token_time = self.now
            self.tel.on_first_token(r.prefill_start_time - r.arrival_time,
                                    self.now - r.prefill_start_time)
            self.running.append(r)
            fused_prefill.remove(r)
        # token emission + completion
        self.res.total_tokens += b
        for r in list(self.running):
            r.sim_emit_token()
            if r.done:
                r.state = RequestState.FINISHED
                r.finish_time = self.now
                self.tel.on_completion(r.output_len)
                self.blocks.free(r.rid)
                self.running.remove(r)
                self.res.finished += 1
        self.res.batch_trace.append(b)

    # -- main loop -----------------------------------------------------------------
    def run(self, max_steps: int = 200_000) -> SimResult:
        for r in self.waiting:
            self.tel.on_arrival(r.arrival_time, r.prompt_len)
        pending_prefill = self.pending_prefill
        steps = 0
        while (self.waiting or self.running or pending_prefill) \
                and steps < max_steps:
            steps += 1
            # idle-advance to next arrival if nothing to do
            if not self.running and not pending_prefill and self.waiting \
                    and self.waiting[0].arrival_time > self.now:
                self.now = self.waiting[0].arrival_time
            tel = self._snapshot()
            decision = self.policy.step(tel)
            self.res.decisions.append(decision)
            admitted = self._admit(decision)
            self._preempt_if_needed()
            if self.serve.chunked_prefill:
                pending_prefill.extend(admitted)
                budget = decision.chunk_budget \
                    or self.serve.chunk_budget_tokens
                if budget <= 0 and pending_prefill and not self.running:
                    # engine-mirrored livelock guard: no decodes and no
                    # budget would spin no-op steps forever
                    budget = self.prefill_chunk \
                        or pending_prefill[0].prompt_len
                self._decode_step(pending_prefill, budget)
            else:
                if admitted:
                    self._prefill_step(admitted)
                if self.running:
                    self._decode_step([], 0)
            # no physical pos rows to clear in the sim — drain the
            # eviction queue so it cannot grow for the run's lifetime
            self.blocks.take_released()
        self.res.duration_s = self.now
        ttfts = sorted(r.first_token_time - r.arrival_time
                       for r in self._all if r.first_token_time >= 0)
        if ttfts:
            self.res.ttft_p90_s = ttfts[int(0.9 * (len(ttfts) - 1))]
            self.res.ttft_mean_s = sum(ttfts) / len(ttfts)
        served = [r for r in self._all
                  if r.first_token_time >= 0 and r.prefill_start_time >= 0]
        if served:
            self.res.ttft_queue_mean_s = sum(
                r.prefill_start_time - r.arrival_time for r in served) \
                / len(served)
            self.res.ttft_prefill_mean_s = sum(
                r.first_token_time - r.prefill_start_time for r in served) \
                / len(served)
        if self.tel.lane_occ:
            self.res.prefill_lane_occupancy = \
                sum(self.tel.lane_occ) / len(self.tel.lane_occ)
        if self._tbts:
            s = sorted(self._tbts)
            self.res.tbt_ms_mean = sum(s) / len(s)
            self.res.tbt_ms_p95 = s[int(0.95 * (len(s) - 1))]
        if self._sla_steps:
            self.res.sla_attainment = self._sla_ok / self._sla_steps
        if self.res.batch_trace:
            self.res.mean_batch = sum(self.res.batch_trace) / len(self.res.batch_trace)
        self.res.prefix_hit_tokens = self.blocks.prefix_hit_tokens
        self.res.prefix_query_tokens = self.blocks.prefix_query_tokens
        self.res.prefix_hit_rate = self.blocks.prefix_hit_rate
        self.res.cache_evictions = self.blocks.cache_evictions
        return self.res
