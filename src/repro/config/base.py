"""Config dataclasses for the repro framework.

Everything is a frozen dataclass so configs hash/compare cleanly and can be
used as static args to jit'd functions.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Tuple


class ArchFamily(str, enum.Enum):
    DENSE = "dense"
    MOE = "moe"
    SSM = "ssm"
    HYBRID = "hybrid"      # RG-LRU + local attention (RecurrentGemma)
    ENCDEC = "encdec"      # audio/enc-dec backbone (Seamless M4T)
    VLM = "vlm"            # decoder + cross-attn image layers


class AttentionKind(str, enum.Enum):
    FULL = "full"                  # causal full attention
    SLIDING = "sliding"            # sliding-window causal attention
    LOCAL_HYBRID = "local_hybrid"  # RecurrentGemma local attention (in hybrid blocks)
    NONE = "none"                  # attention-free (pure SSM)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    num_experts_per_tok: int
    num_shared_experts: int = 0
    expert_ff_dim: int = 0          # d_ff of each routed expert
    shared_ff_dim: int = 0          # d_ff of the shared expert block (total)
    router_aux_loss_coef: float = 0.001
    capacity_factor: float = 1.25   # dense-dispatch capacity per expert
    # serving-path dispatch: True = exact worst-case capacity (bitwise
    # chunking-invariant — CPU engine/tests); False = capacity_factor
    # dispatch (production TPU: bounds the (G,E,C) tensors; §Perf iter G)
    inference_no_drop: bool = True


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128            # N (SSD state size)
    head_dim: int = 64              # P (channels per SSD head)
    num_heads: int = 0              # derived: d_inner / head_dim if 0
    conv_width: int = 4
    chunk_size: int = 256           # SSD chunked-scan block length
    expand: int = 2                 # d_inner = expand * d_model


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma recurrent block (RG-LRU)."""
    lru_width: int = 0              # defaults to d_model if 0
    conv_width: int = 4
    window_size: int = 2048         # local-attention window of the hybrid attn blocks
    block_pattern: Tuple[str, ...] = ("recurrent", "recurrent", "attention")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: ArchFamily
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # derived d_model // num_heads if 0
    attention: AttentionKind = AttentionKind.FULL
    sliding_window: int = 0         # >0 for AttentionKind.SLIDING
    qkv_bias: bool = False          # Qwen-style attention bias
    rope_theta: float = 10000.0
    rms_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # enc-dec (audio backbone)
    encoder_layers: int = 0
    # VLM: 1 cross-attn layer inserted every `vlm_cross_every` decoder layers
    vlm_cross_every: int = 0
    num_cross_layers: int = 0
    dtype: str = "bfloat16"
    source: str = ""                # citation for the config

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if not self.num_heads:
            return 0
        return self.d_model // self.num_heads

    @property
    def is_attention_free(self) -> bool:
        return self.attention == AttentionKind.NONE

    def param_count(self) -> int:
        """Total parameter count (approximate, matches the builder's tensors)."""
        d, f, V = self.d_model, self.d_ff, self.vocab_size
        h = self.resolved_head_dim
        emb = V * d * (1 if self.tie_embeddings else 2)
        n = emb
        # Attention-bearing layers
        att = (self.num_heads * h + 2 * self.num_kv_heads * h) * d \
            + self.num_heads * h * d
        mlp = 3 * d * f  # SwiGLU
        if self.family in (ArchFamily.DENSE, ArchFamily.VLM):
            n += self.num_layers * (att + mlp + 2 * d)
            if self.family == ArchFamily.VLM and self.num_cross_layers:
                n += self.num_cross_layers * (att + mlp + 2 * d)
        elif self.family == ArchFamily.MOE:
            m = self.moe
            routed = 3 * d * m.expert_ff_dim * m.num_experts
            shared = 3 * d * m.shared_ff_dim if m.shared_ff_dim else 0
            router = d * m.num_experts
            n += self.num_layers * (att + routed + shared + router + 2 * d)
        elif self.family == ArchFamily.SSM:
            s = self.ssm
            d_in = s.expand * d
            nheads = s.num_heads or d_in // s.head_dim
            per = d * (2 * d_in + 2 * nheads * s.state_dim if False else 0)
            # mamba2: in_proj d->(2*d_in + 2*n_groups*N + nheads), out_proj d_in->d
            per = d * (2 * d_in + 2 * s.state_dim + nheads) + d_in * d \
                + s.conv_width * (d_in + 2 * s.state_dim) + d_in + 2 * nheads
            n += self.num_layers * (per + d)
        elif self.family == ArchFamily.HYBRID:
            r = self.rglru
            w = r.lru_width or d
            rec = d * (2 * w) + w * d + r.conv_width * w + 3 * w  # proj + conv + gates(diag-ish)
            rec = 2 * d * w + w * d + r.conv_width * w + 2 * w * w + 2 * w
            pat = r.block_pattern
            n_att = sum(1 for p in self.layer_kinds() if p == "attention")
            n_rec = self.num_layers - n_att
            n += n_att * (att + mlp + 2 * d) + n_rec * (rec + mlp + 2 * d)
        elif self.family == ArchFamily.ENCDEC:
            # encoder: self-att + mlp; decoder: self + cross + mlp
            n += self.encoder_layers * (att + mlp + 2 * d)
            n += self.num_layers * (2 * att + mlp + 3 * d)
        n += d  # final norm
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top-k experts only)."""
        if self.family != ArchFamily.MOE:
            return self.param_count()
        d = self.d_model
        m = self.moe
        h = self.resolved_head_dim
        att = (self.num_heads * h + 2 * self.num_kv_heads * h) * d \
            + self.num_heads * h * d
        routed_active = 3 * d * m.expert_ff_dim * m.num_experts_per_tok
        shared = 3 * d * m.shared_ff_dim if m.shared_ff_dim else 0
        router = d * m.num_experts
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return emb + self.num_layers * (att + routed_active + shared + router + 2 * d) + d

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind sequence ('attention'|'recurrent'|'ssm'|'dense'|'cross')."""
        if self.family == ArchFamily.HYBRID:
            pat = self.rglru.block_pattern
            return tuple(pat[i % len(pat)] for i in range(self.num_layers))
        if self.family == ArchFamily.SSM:
            return tuple("ssm" for _ in range(self.num_layers))
        return tuple("attention" for _ in range(self.num_layers))

    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        """KV-cache bytes per token per request — the scheduler's memory model.

        For bounded/constant-state families this is the *asymptotic marginal*
        cost (0 for SSM; window-capped handled in core.memory_model).
        """
        h = self.resolved_head_dim
        if self.family == ArchFamily.SSM:
            return 0
        n_att = sum(1 for k in self.layer_kinds() if k == "attention")
        layers = n_att if self.family == ArchFamily.HYBRID else self.num_layers
        if self.family == ArchFamily.ENCDEC:
            layers = self.num_layers  # decoder self-attn only grows
        return 2 * layers * self.num_kv_heads * h * dtype_bytes


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False

    @property
    def shape(self) -> Tuple[int, ...]:
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self) -> Tuple[str, ...]:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Serving engine + scheduler configuration (paper's knobs)."""
    policy: str = "combined"       # static | memory | sla | combined
    b_min: int = 1                 # B_min
    b_max: int = 256               # B_max (static policy uses this as THE batch size)
    d_sla_ms: float = 0.0          # D_SLA; 0 => no SLA constraint
    eps_d_ms: float = 2.0          # ε_D latency tolerance band
    # per-request goodput SLOs (DESIGN §15), distinct from the per-step
    # controller SLA d_sla_ms: a finished request meets the SLA iff its
    # TTFT <= ttft_sla_s AND its mean TBT <= tbt_sla_ms; goodput counts
    # only SLA-met requests' tokens. 0 disables that check (every
    # finished request then passes it). Verdicts stamp at retirement in
    # the engine and at finish in the sim (rejected requests never meet).
    ttft_sla_s: float = 0.0
    tbt_sla_ms: float = 0.0
    eps_m: float = 0.05            # ε_M memory-overflow probability budget
    alpha: int = 16                # Alg 2 window-width control α
    delta: int = 4                 # Alg 2 anti-noise relaxation δ
    block_size: int = 16           # KV allocator block granularity (tokens)
    # physically paged KV cache (DESIGN §9): K/V live in shared
    # (layers, num_blocks, block_size, KV, hd) pools indexed by the
    # BlockManager's per-request block tables; lane promotion, finish
    # compaction and eviction become O(1) table edits. False keeps the
    # legacy contiguous per-slot cache (n_prefill_lanes=1 bit-for-bit).
    paged_kv: bool = False
    # ref-counted automatic prefix sharing on the paged pool (DESIGN §10):
    # per-block refcounts + content-hash index; admission maps shared full
    # prompt blocks with zero copies and prefills only the suffix; free()
    # becomes decref with blocks held as evictable LRU cache. Requires
    # paged_kv and an attention-only family (gated per-engine).
    prefix_cache: bool = False
    kv_pool_tokens: int = 0        # η; 0 => derived from memory budget
    hbm_budget_bytes: int = 0      # M_max source; 0 => engine-provided
    l0_refresh_interval: int = 32  # L0 offline refresh cadence (intervals)
    chunked_prefill: bool = False  # PD-fusion mode
    chunk_budget_tokens: int = 512 # base token budget per fused step
    # PD-fusion lanes (DESIGN §6): spare physical cache rows past the decode
    # buckets; each lane chunk-prefills one request per interval, the
    # interval's chunk_budget is packed across occupied lanes
    n_prefill_lanes: int = 1
    # lane packer policy: "fifo" (arrival order — 1 lane reproduces the
    # single-spare-row engine bit-for-bit) | "srf" (shortest remaining first)
    prefill_pack: str = "fifo"
    max_new_tokens: int = 128
    batch_buckets: Tuple[int, ...] = ()  # () => exact batch (CPU), else bucketized
    # two-tier KV memory (DESIGN §11): a host-side swap pool of this many
    # blocks. 0 (default) keeps today's recompute-only preemption; > 0 lets
    # the preemption path choose per-victim between swapping the victim's
    # blocks to host RAM (kept as a swap ledger, restored on re-admission)
    # and recompute, using the cost-model crossover
    # pcie_ms(blocks) < reprefill_ms(context). Requires paged_kv in the
    # engine; attention-only families (shared gate with prefix_cache).
    swap_space_blocks: int = 0
    # preemption flavor when the pool would overflow: "recompute" throws
    # the victim's KV away (vLLM recompute; the only choice when
    # swap_space_blocks == 0), "auto" applies the DESIGN §11 cost-model
    # crossover per victim, "swap" forces swap-out whenever it is possible
    # at all (host space, no shared blocks — else recompute fallback)
    preempt: str = "auto"
    # async dispatch-ahead pipeline (DESIGN §14): how many device steps may
    # be in flight while the host schedules the next interval. 0 keeps the
    # fully synchronous loop (dispatch + retire inside one interval); 1
    # overlaps interval N+1's admission/lane-packing/table edits with
    # interval N's device step, reading telemetry one interval late (Alg 1
    # tolerates stale snapshots by design). Outputs are bitwise-identical
    # at every depth — only wall-clock attribution changes.
    overlap_depth: int = 0
    # mesh-sharded serving (DESIGN §12): device mesh shape for the engine,
    # last axis = "model" (tensor parallelism over kv-heads / head_dim),
    # leading axes = ("data",) or ("pod", "data"). () keeps today's
    # single-device engine. Under a mesh, hbm_budget_bytes / kv_pool_tokens
    # are PER-CHIP quantities: the pool's token capacity scales with the
    # model-axis size (each chip holds 1/m of every token's KV bytes).
    mesh_shape: Tuple[int, ...] = ()

    @property
    def model_axis_size(self) -> int:
        """Size of the mesh's "model" (tensor-parallel) axis — by
        convention the LAST axis of mesh_shape (DESIGN §5/§12)."""
        return self.mesh_shape[-1] if self.mesh_shape else 1


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    global_batch: int = 8
    seq_len: int = 256
    steps: int = 200
    lr: float = 3e-4
    warmup_steps: int = 20
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    grad_clip: float = 1.0
    seed: int = 0
    log_every: int = 10
    remat: bool = True
