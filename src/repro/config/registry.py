"""Architecture registry: --arch <id> -> ModelConfig (full + reduced variants)."""
from __future__ import annotations

import importlib
from typing import Callable, Dict, List

from repro.config.base import ModelConfig

_REGISTRY: Dict[str, Dict[str, Callable[[], ModelConfig]]] = {}

# module names can't contain '-' or '.', map arch ids to module names
_ARCH_MODULES = {
    "qwen2-moe-a2.7b": "qwen2_moe_a2p7b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "qwen1.5-32b": "qwen1p5_32b",
    "granite-3-8b": "granite_3_8b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "starcoder2-7b": "starcoder2_7b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "mamba2-2.7b": "mamba2_2p7b",
    "llama-3.2-vision-90b": "llama_3p2_vision_90b",
}


def register(arch_id: str, full: Callable[[], ModelConfig],
             reduced: Callable[[], ModelConfig]) -> None:
    _REGISTRY[arch_id] = {"full": full, "reduced": reduced}


def _ensure_loaded(arch_id: str) -> None:
    if arch_id in _REGISTRY:
        return
    mod = _ARCH_MODULES.get(arch_id)
    if mod is None:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCH_MODULES)}")
    importlib.import_module(f"repro.configs.{mod}")


def get_config(arch_id: str, variant: str = "full") -> ModelConfig:
    _ensure_loaded(arch_id)
    return _REGISTRY[arch_id][variant]()


def list_archs() -> List[str]:
    return sorted(_ARCH_MODULES)
