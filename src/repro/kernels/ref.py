"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k, v, q_pos, k_pos, *, window: int = 0):
    """q: (B, H, hd); k/v: (B, S, KV, hd); q_pos: (B,); k_pos: (B, S)."""
    B, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qh = q.reshape(B, KV, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bkgd,bskd->bkgs", qh, k.astype(jnp.float32))
    scores = scores / math.sqrt(hd)
    mask = (k_pos >= 0) & (k_pos <= q_pos[:, None])
    if window:
        mask = mask & (k_pos > q_pos[:, None] - window)
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)


def paged_view(pool_k, pool_v, pool_pos, tables):
    """Gather a per-request contiguous (B, MB*bs) view of the paged pools
    (DESIGN §9) — the canonical block-table gather, shared by the paged
    decode oracle below and `models.layers.paged_view` (the production
    non-kernel path), so the two can never diverge.

    Logical block j of request b sits at view indices [j*bs, (j+1)*bs), so
    a token at absolute position p lands at view index p — the same index
    it has in a non-ring contiguous cache row, which keeps the paged and
    contiguous layouts bitwise comparable. Unallocated table entries (-1)
    read as empty slots (K/V = 0, pos = -1)."""
    NB, bs = pool_k.shape[:2]
    B, MB = tables.shape
    base = jnp.where(tables >= 0, tables * bs, NB * bs)        # (B, MB)
    idx = (base[:, :, None] + jnp.arange(bs)[None, None, :]).reshape(B, MB * bs)
    kf = pool_k.reshape((NB * bs,) + pool_k.shape[2:])
    vf = pool_v.reshape((NB * bs,) + pool_v.shape[2:])
    pf = pool_pos.reshape(NB * bs)
    k = kf.at[idx].get(mode="fill", fill_value=0)
    v = vf.at[idx].get(mode="fill", fill_value=0)
    kpos = pf.at[idx].get(mode="fill", fill_value=-1)
    return k, v, kpos


def paged_decode_attention_ref(q, k_pool, v_pool, q_pos, kpos_pool, tables,
                               *, window: int = 0):
    """Gather-then-attend oracle for the paged kernel (DESIGN §9).

    q: (B, H, hd); k/v_pool: (NB, bs, KV, hd); q_pos: (B,);
    kpos_pool: (NB, bs); tables: (B, MB), -1 = unallocated."""
    k, v, kpos = paged_view(k_pool, v_pool, kpos_pool, tables)
    return decode_attention_ref(q, k, v, q_pos, kpos, window=window)


def flash_attention_ref(q, k, v, q_pos, k_pos, *, window: int = 0,
                        causal: bool = True):
    """q: (B, Tq, H, hd); k/v: (B, Tk, KV, hd); q_pos: (B,Tq); k_pos: (B,Tk)."""
    B, Tq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qh = q.reshape(B, Tq, KV, G, hd).astype(jnp.float32)
    scores = jnp.einsum("btkgd,bskd->bkgts", qh, k.astype(jnp.float32))
    scores = scores / math.sqrt(hd)
    mask = (k_pos[:, None, :] >= 0)
    if causal:
        mask = mask & (k_pos[:, None, :] <= q_pos[:, :, None])
    if window:
        mask = mask & (k_pos[:, None, :] > q_pos[:, :, None] - window)
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", p, v.astype(jnp.float32))
    return out.reshape(B, Tq, H * hd).reshape(B, Tq, H, hd).astype(q.dtype)


def ssd_intra_ref(xdt, cum_a, Br, Cr):
    """Intra-chunk SSD term + per-chunk states (the Pallas kernel's scope).

    xdt:   (B, nc, Q, H, P)  dt-scaled inputs
    cum_a: (B, nc, Q, H)     within-chunk cumulative log-decay
    Br/Cr: (B, nc, Q, N)
    Returns y_intra (B, nc, Q, H, P), s_chunk (B, nc, H, P, N).
    """
    f32 = jnp.float32
    xdt, cum_a = xdt.astype(f32), cum_a.astype(f32)
    Br, Cr = Br.astype(f32), Cr.astype(f32)
    Q = xdt.shape[2]
    li = cum_a[:, :, :, None, :]
    lj = cum_a[:, :, None, :, :]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(li - lj), 0.0)
    cb = jnp.einsum("bzin,bzjn->bzij", Cr, Br)
    w = cb[..., None] * L
    y_intra = jnp.einsum("bzijh,bzjhp->bzihp", w, xdt)
    seg_end = cum_a[:, :, -1:, :]
    decay_to_end = jnp.exp(seg_end - cum_a)
    s_chunk = jnp.einsum("bzjn,bzjhp->bzhpn", Br, xdt * decay_to_end[..., None])
    return y_intra, s_chunk


def rmsnorm_ref(x, w, *, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    ms = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(ms + eps) * (1.0 + w.astype(jnp.float32))
    return y.astype(x.dtype)


def rglru_scan_ref(a, bx, h0):
    """h_t = a_t * h_{t-1} + bx_t. a/bx: (B, T, W) fp32; h0: (B, W).

    Returns (h_all (B,T,W), h_T (B,W))."""
    def combine(left, right):
        al, bl = left
        ar, br = right
        return al * ar, ar * bl + br

    bx = bx.at[:, 0].add(a[:, 0] * h0)
    _, h_all = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h_all, h_all[:, -1]
