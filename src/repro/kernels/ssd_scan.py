"""Mamba2 SSD intra-chunk Pallas kernel.

Computes, per (batch, chunk, head): the quadratic intra-chunk output
Y = (C B^T o L) . xdt and the chunk state contribution
S = (B * decay_to_end)^T xdt. The cheap inter-chunk recurrence stays in JAX
(lax.scan over nc) — the kernel covers the O(T * Q * (N + P)) hot loop.

Tiles: Q x N and Q x P matrices in VMEM; Q (chunk len, default 256), N
(state 128) and P (head dim 64) are MXU-aligned at full scale.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xdt_ref, cum_a_ref, br_ref, cr_ref, y_ref, s_ref):
    xdt = xdt_ref[0, 0, :, 0].astype(jnp.float32)        # (Q, P)
    ca = cum_a_ref[0, 0, :, 0].astype(jnp.float32)       # (Q,)
    br = br_ref[0, 0].astype(jnp.float32)                # (Q, N)
    cr = cr_ref[0, 0].astype(jnp.float32)                # (Q, N)
    Q = xdt.shape[0]

    li = ca[:, None]
    lj = ca[None, :]
    tri = jnp.tril(jnp.ones((Q, Q), jnp.bool_))
    L = jnp.where(tri, jnp.exp(li - lj), 0.0)            # (Q, Q)
    cb = jnp.dot(cr, br.T)                               # (Q, Q)
    y = jnp.dot(cb * L, xdt)                             # (Q, P)
    decay_end = jnp.exp(ca[-1] - ca)                     # (Q,)
    s = jnp.dot((br * decay_end[:, None]).T, xdt)        # (N, P)

    y_ref[0, 0, :, 0] = y.astype(y_ref.dtype)
    s_ref[0, 0, 0] = s.T.astype(s_ref.dtype)             # (P, N)


def ssd_intra_kernel(xdt, cum_a, Br, Cr, *, interpret: bool = True):
    """xdt: (B, nc, Q, H, P); cum_a: (B, nc, Q, H); Br/Cr: (B, nc, Q, N).

    Returns y_intra (B, nc, Q, H, P) fp32, s_chunk (B, nc, H, P, N) fp32."""
    B, nc, Q, H, P = xdt.shape
    N = Br.shape[-1]
    grid = (B, nc, H)
    y, s = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, 1, P), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, c, h: (b, c, 0, h)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c, h: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c, h: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, 1, P), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, 1, P, N), lambda b, c, h: (b, c, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nc, Q, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, nc, H, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(xdt, cum_a, Br, Cr)
    return y, s
