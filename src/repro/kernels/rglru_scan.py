"""RG-LRU linear-recurrence Pallas kernel (RecurrentGemma hot loop).

h_t = a_t * h_{t-1} + bx_t over the time axis, blocked over the width axis:
grid = (batch, width_blocks); each program runs the sequential recurrence in
VMEM with a fori_loop. On TPU the (T, WB) tile streams HBM->VMEM once —
this is the memory-optimal layout for a bandwidth-bound elementwise scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, bx_ref, h0_ref, y_ref, hT_ref):
    a = a_ref[0].astype(jnp.float32)        # (T, WB)
    bx = bx_ref[0].astype(jnp.float32)      # (T, WB)
    h0 = h0_ref[0].astype(jnp.float32)      # (WB,)
    T = a.shape[0]

    def body(t, carry):
        h = carry
        h = a[t] * h + bx[t]
        y_ref[0, t] = h.astype(y_ref.dtype)
        return h

    hT = jax.lax.fori_loop(0, T, body, h0)
    hT_ref[0] = hT.astype(hT_ref.dtype)


def rglru_scan_kernel(a, bx, h0, *, block_w: int = 128,
                      interpret: bool = True):
    """a, bx: (B, T, W); h0: (B, W). Returns (h_all (B,T,W), h_T (B,W))."""
    B, T, W = a.shape
    bw = min(block_w, W)
    assert W % bw == 0, "pad width to block multiple"
    nw = W // bw
    grid = (B, nw)
    y, hT = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, T, bw), lambda b, w: (b, 0, w)),
            pl.BlockSpec((1, T, bw), lambda b, w: (b, 0, w)),
            pl.BlockSpec((1, bw), lambda b, w: (b, w)),
        ],
        out_specs=[
            pl.BlockSpec((1, T, bw), lambda b, w: (b, 0, w)),
            pl.BlockSpec((1, bw), lambda b, w: (b, w)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, W), jnp.float32),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        interpret=interpret,
    )(a, bx, h0)
    return y, hT
