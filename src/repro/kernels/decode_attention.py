"""Flash-decode GQA attention Pallas kernel (the serving hot spot).

One new query token per sequence against a (possibly ring-buffer) KV cache.
Grid = (batch, kv_head, kv_blocks); the kv-block axis is innermost and
accumulates an online softmax in VMEM scratch. Masking is position-based
(absolute positions per cache slot, -1 = empty), identical to the model's
semantics — so ring buffers / sliding windows need no extra code.

TPU notes: tiles are MXU-friendly when G (= q_heads/kv_heads) and head_dim
are multiples of 8/128; the reduced test shapes run under interpret=True.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(qpos_ref, q_ref, k_ref, v_ref, kpos_ref, o_ref,
            m_ref, l_ref, acc_ref, *, window: int, block_s: int):
    s = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                  # (G, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)               # (BS, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)               # (BS, hd)
    kpos = kpos_ref[0]                                   # (BS,)
    qpos = qpos_ref[0, 0]                                # scalar

    hd = q.shape[-1]
    scores = jnp.dot(q, k.T) / math.sqrt(hd)             # (G, BS)
    mask = (kpos >= 0) & (kpos <= qpos)
    if window:
        mask = mask & (kpos > qpos - window)
    scores = jnp.where(mask[None, :], scores, NEG_INF)

    m_prev = m_ref[...]                                  # (G, 1)
    m_cur = jnp.max(scores, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)                          # (G, BS)
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(p, v)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(s == ns - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_kernel(q, k, v, q_pos, k_pos, *, window: int = 0,
                            block_s: int = 128, interpret: bool = True):
    """q: (B, H, hd); k/v: (B, S, KV, hd); q_pos: (B,); k_pos: (B, S).

    Returns (B, H, hd)."""
    B, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    bs = min(block_s, S)
    ns = -(-S // bs)
    qr = q.reshape(B, KV, G, hd)
    qpos2 = q_pos.reshape(B, 1).astype(jnp.int32)

    grid = (B, KV, ns)
    out = pl.pallas_call(
        functools.partial(_kernel, window=window, block_s=bs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, s: (b, 0)),            # qpos
            pl.BlockSpec((1, 1, G, hd), lambda b, h, s: (b, h, 0, 0)),  # q
            pl.BlockSpec((1, bs, 1, hd), lambda b, h, s: (b, s, h, 0)),  # k
            pl.BlockSpec((1, bs, 1, hd), lambda b, h, s: (b, s, h, 0)),  # v
            pl.BlockSpec((1, bs), lambda b, h, s: (b, s)),           # kpos
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),   # running max m
            pltpu.VMEM((G, 1), jnp.float32),   # running denom l
            pltpu.VMEM((G, hd), jnp.float32),  # weighted-value accumulator
        ],
        interpret=interpret,
    )(qpos2, qr, k, v, k_pos.astype(jnp.int32))
    return out.reshape(B, H, hd)
