"""Flash-decode GQA attention Pallas kernels (the serving hot spot).

One new query token per sequence against the KV cache, in two layouts:

* contiguous (`decode_attention_kernel`): k/v are per-slot (B, S, KV, hd)
  rows; grid = (batch, kv_head, kv_blocks) over the contiguous S axis.
* paged (`paged_decode_attention_kernel`, DESIGN §9): k/v live in shared
  (num_blocks, block_size, KV, hd) pools and the kv-block grid axis walks
  the per-request block table instead of a contiguous row — the table is a
  scalar-prefetch operand so the BlockSpec index maps can chase it.

Both accumulate an online softmax in VMEM scratch. Masking is
position-based (absolute positions per cache slot, -1 = empty), identical
to the model's semantics — ring buffers / sliding windows / ragged paged
tails need no extra code.

TPU notes: tiles are MXU-friendly when G (= q_heads/kv_heads) and head_dim
are multiples of 8/128; the reduced test shapes run under interpret=True.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_accumulate(s, ns, q, k, v, mask, o_ref, m_ref, l_ref, acc_ref):
    """One kv-tile of the online-softmax accumulate, shared by the
    contiguous and paged decode kernels (which differ only in how the tile
    is addressed and masked).

    q: (G, hd) fp32; k/v: (BS, hd) fp32; mask: (BS,) bool. Initializes the
    VMEM scratch on the first tile and writes o_ref on the last."""
    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    hd = q.shape[-1]
    scores = jnp.dot(q, k.T) / math.sqrt(hd)             # (G, BS)
    scores = jnp.where(mask[None, :], scores, NEG_INF)

    m_prev = m_ref[...]                                  # (G, 1)
    m_cur = jnp.max(scores, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)                          # (G, BS)
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(p, v)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(s == ns - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _kernel(qpos_ref, q_ref, k_ref, v_ref, kpos_ref, o_ref,
            m_ref, l_ref, acc_ref, *, window: int, block_s: int):
    s = pl.program_id(2)
    ns = pl.num_programs(2)

    q = q_ref[0, 0].astype(jnp.float32)                  # (G, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)               # (BS, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)               # (BS, hd)
    kpos = kpos_ref[0]                                   # (BS,)
    qpos = qpos_ref[0, 0]                                # scalar

    mask = (kpos >= 0) & (kpos <= qpos)
    if window:
        mask = mask & (kpos > qpos - window)
    _flash_accumulate(s, ns, q, k, v, mask, o_ref, m_ref, l_ref, acc_ref)


def decode_attention_kernel(q, k, v, q_pos, k_pos, *, window: int = 0,
                            block_s: int = 128, interpret: bool = True):
    """q: (B, H, hd); k/v: (B, S, KV, hd); q_pos: (B,); k_pos: (B, S).

    Returns (B, H, hd)."""
    B, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    bs = min(block_s, S)
    ns = -(-S // bs)
    qr = q.reshape(B, KV, G, hd)
    qpos2 = q_pos.reshape(B, 1).astype(jnp.int32)

    grid = (B, KV, ns)
    out = pl.pallas_call(
        functools.partial(_kernel, window=window, block_s=bs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, s: (b, 0)),            # qpos
            pl.BlockSpec((1, 1, G, hd), lambda b, h, s: (b, h, 0, 0)),  # q
            pl.BlockSpec((1, bs, 1, hd), lambda b, h, s: (b, s, h, 0)),  # k
            pl.BlockSpec((1, bs, 1, hd), lambda b, h, s: (b, s, h, 0)),  # v
            pl.BlockSpec((1, bs), lambda b, h, s: (b, s)),           # kpos
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),   # running max m
            pltpu.VMEM((G, 1), jnp.float32),   # running denom l
            pltpu.VMEM((G, hd), jnp.float32),  # weighted-value accumulator
        ],
        interpret=interpret,
    )(qpos2, qr, k, v, k_pos.astype(jnp.int32))
    return out.reshape(B, H, hd)


def _paged_kernel(tbl_ref, qpos_ref, q_ref, k_ref, v_ref, kpos_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, window: int):
    b = pl.program_id(0)
    s = pl.program_id(2)
    ns = pl.num_programs(2)

    q = q_ref[0, 0].astype(jnp.float32)                  # (G, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)               # (BS, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)               # (BS, hd)
    kpos = kpos_ref[0]                                   # (BS,)
    qpos = qpos_ref[0, 0]                                # scalar

    # unallocated table slots (-1) were clamped to physical block 0 by the
    # index map; mask the whole tile so block 0's real tenant is invisible
    mask = (kpos >= 0) & (kpos <= qpos) & (tbl_ref[b, s] >= 0)
    if window:
        mask = mask & (kpos > qpos - window)
    _flash_accumulate(s, ns, q, k, v, mask, o_ref, m_ref, l_ref, acc_ref)


def paged_decode_attention_kernel(q, k_pool, v_pool, q_pos, kpos_pool,
                                  tables, *, window: int = 0,
                                  interpret: bool = True):
    """Paged flash decode (DESIGN §9).

    q: (B, H, hd); k_pool/v_pool: (NB, bs, KV, hd) shared physical pools;
    q_pos: (B,); kpos_pool: (NB, bs) absolute positions (-1 = empty);
    tables: (B, MB) physical block ids per request (-1 = unallocated).

    Grid = (batch, kv_head, table_slot): the innermost axis walks the block
    TABLE, not physical memory — `tables` rides in as a scalar-prefetch
    operand so the k/v/kpos BlockSpec index maps resolve tables[b, s] to the
    physical block to stream. Returns (B, H, hd)."""
    B, H, hd = q.shape
    NB, bs, KV, _ = k_pool.shape
    MB = tables.shape[1]
    G = H // KV
    qr = q.reshape(B, KV, G, hd)
    qpos2 = q_pos.reshape(B, 1).astype(jnp.int32)
    tbl = tables.astype(jnp.int32)

    def pool_map(b, h, s, t):
        return (jnp.maximum(t[b, s], 0), 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, MB),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, s, t: (b, 0)),             # qpos
            pl.BlockSpec((1, 1, G, hd), lambda b, h, s, t: (b, h, 0, 0)),  # q
            pl.BlockSpec((1, bs, 1, hd), pool_map),                      # k
            pl.BlockSpec((1, bs, 1, hd), pool_map),                      # v
            pl.BlockSpec((1, bs),
                         lambda b, h, s, t: (jnp.maximum(t[b, s], 0), 0)),  # kpos
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, s, t: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),   # running max m
            pltpu.VMEM((G, 1), jnp.float32),   # running denom l
            pltpu.VMEM((G, hd), jnp.float32),  # weighted-value accumulator
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(tbl, qpos2, qr, k_pool, v_pool, kpos_pool.astype(jnp.int32))
    return out.reshape(B, H, hd)
