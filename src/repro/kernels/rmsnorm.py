"""Fused RMSNorm Pallas kernel.

Bandwidth-bound elementwise hot spot: one HBM->VMEM pass computes the
mean-square, rsqrt and scale in registers instead of XLA's multi-pass
lowering. Grid over row blocks; the full feature dim lives in one VMEM
tile (d_model <= ~16k fits easily at fp32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)           # (BR, d)
    w = w_ref[...].astype(jnp.float32)           # (d,)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps) * (1.0 + w)[None, :]
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_kernel(x, w, *, eps: float = 1e-6, block_rows: int = 128,
                   interpret: bool = True):
    """x: (..., d); w: (d,). Returns rms_norm(x) * (1 + w)."""
    orig_shape = x.shape
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    n = xf.shape[0]
    br = min(block_rows, n)
    pad = (-n) % br
    if pad:
        xf = jnp.concatenate([xf, jnp.zeros((pad, d), x.dtype)], axis=0)
    grid = ((n + pad) // br,)
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(((n + pad), d), x.dtype),
        interpret=interpret,
    )(xf, w)
    if pad:
        out = out[:n]
    return out.reshape(orig_shape)
