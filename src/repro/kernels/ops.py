"""jit'd dispatch wrappers for the Pallas kernels.

On CPU (this container) kernels run under interpret=True; on TPU they lower
natively. `use_kernel=False` routes to the pure-jnp oracle — the serving and
training stacks call these entry points so the backend is a config switch.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import ref
from repro.kernels.decode_attention import (decode_attention_kernel,
                                            paged_decode_attention_kernel)
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.rglru_scan import rglru_scan_kernel
from repro.kernels.rmsnorm import rmsnorm_kernel
from repro.kernels.ssd_scan import ssd_intra_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("window", "use_kernel", "block_s"))
def decode_attention(q, k, v, q_pos, k_pos, *, window: int = 0,
                     use_kernel: bool = True, block_s: int = 128):
    if not use_kernel:
        return ref.decode_attention_ref(q, k, v, q_pos, k_pos, window=window)
    return decode_attention_kernel(q, k, v, q_pos, k_pos, window=window,
                                   block_s=block_s, interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("window", "use_kernel"))
def paged_decode_attention(q, k_pool, v_pool, q_pos, kpos_pool, tables, *,
                           window: int = 0, use_kernel: bool = True):
    """Flash decode through the paged KV pools + block tables (DESIGN §9)."""
    if not use_kernel:
        return ref.paged_decode_attention_ref(q, k_pool, v_pool, q_pos,
                                              kpos_pool, tables, window=window)
    return paged_decode_attention_kernel(q, k_pool, v_pool, q_pos, kpos_pool,
                                         tables, window=window,
                                         interpret=not _on_tpu())


def paged_decode_attention_tp(q, k_pool, v_pool, q_pos, kpos_pool, tables, *,
                              mesh, window: int = 0, use_kernel: bool = True):
    """Tensor-parallel paged flash decode via shard_map (DESIGN §12).

    The paged kernel's grid is (batch, kv_head, table_slot) — per-kv-head
    work is fully independent — so TP is a shard_map over the "model"
    axis: each shard streams its kv-head slice of the K/V pools against
    its q-head slice (heads are kv-major, so H/m q-heads pair with KV/m
    kv-heads), with the block table and pos map replicated. No collective
    runs inside the kernel, which keeps shard outputs bitwise identical
    to the single-device kernel. Requires KV % model_axis == 0 — head_dim
    sharding would split the softmax contraction and is storage-only
    (callers fall back to the gathered single-device path)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    KV = k_pool.shape[2]
    m = int(mesh.shape["model"])
    if KV % m != 0:
        raise ValueError(f"kv heads {KV} not divisible by model axis {m}")

    def local(q, kp, vp, qp, pp, tb):
        if not use_kernel:
            return ref.paged_decode_attention_ref(q, kp, vp, qp, pp, tb,
                                                  window=window)
        return paged_decode_attention_kernel(q, kp, vp, qp, pp, tb,
                                             window=window,
                                             interpret=not _on_tpu())

    head_spec = P(None, "model", None)
    pool_spec = P(None, None, "model", None)
    return shard_map(
        local, mesh,
        in_specs=(head_spec, pool_spec, pool_spec, P(None), P(None, None),
                  P(None, None)),
        out_specs=head_spec, check_rep=False,
    )(q, k_pool, v_pool, q_pos, kpos_pool, tables)


@functools.partial(jax.jit, static_argnames=("window", "causal", "use_kernel",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, q_pos, k_pos, *, window: int = 0,
                    causal: bool = True, use_kernel: bool = True,
                    block_q: int = 128, block_k: int = 128):
    if not use_kernel:
        return ref.flash_attention_ref(q, k, v, q_pos, k_pos, window=window,
                                       causal=causal)
    return flash_attention_kernel(q, k, v, q_pos, k_pos, window=window,
                                  causal=causal, block_q=block_q,
                                  block_k=block_k, interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def ssd_intra(xdt, cum_a, Br, Cr, *, use_kernel: bool = True):
    if not use_kernel:
        return ref.ssd_intra_ref(xdt, cum_a, Br, Cr)
    return ssd_intra_kernel(xdt, cum_a, Br, Cr, interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("use_kernel", "block_w"))
def rglru_scan(a, bx, h0, *, use_kernel: bool = True, block_w: int = 128):
    if not use_kernel:
        return ref.rglru_scan_ref(a, bx, h0)
    return rglru_scan_kernel(a, bx, h0, block_w=block_w,
                             interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("eps", "use_kernel",
                                             "block_rows"))
def rmsnorm(x, w, *, eps: float = 1e-6, use_kernel: bool = True,
            block_rows: int = 128):
    if not use_kernel:
        return ref.rmsnorm_ref(x, w, eps=eps)
    return rmsnorm_kernel(x, w, eps=eps, block_rows=block_rows,
                          interpret=not _on_tpu())
