"""Causal flash-attention Pallas kernel (prefill / training hot spot).

Grid = (batch, q_head, q_blocks, kv_blocks); kv innermost with online
softmax in VMEM scratch. Position-based masking (supports chunked prefill
against a pre-filled cache and sliding windows). GQA via index-map head
folding: q head h reads kv head h // G.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, qpos_ref, kpos_ref, o_ref,
            m_ref, l_ref, acc_ref, *, window: int, causal: bool):
    t = pl.program_id(3)
    nt = pl.num_programs(3)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                  # (BQ, hd)
    k = k_ref[0, 0].astype(jnp.float32)                  # (BK, hd)
    v = v_ref[0, 0].astype(jnp.float32)                  # (BK, hd)
    qpos = qpos_ref[0]                                   # (BQ,)
    kpos = kpos_ref[0]                                   # (BK,)

    hd = q.shape[-1]
    scores = jnp.dot(q, k.T) / math.sqrt(hd)             # (BQ, BK)
    mask = kpos[None, :] >= 0
    if causal:
        mask = mask & (kpos[None, :] <= qpos[:, None])
    if window:
        mask = mask & (kpos[None, :] > qpos[:, None] - window)
    scores = jnp.where(mask, scores, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(p, v)
    m_ref[...] = m_new

    @pl.when(t == nt - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, q_pos, k_pos, *, window: int = 0,
                           causal: bool = True, block_q: int = 128,
                           block_k: int = 128, interpret: bool = True):
    """q: (B, Tq, H, hd); k/v: (B, Tk, KV, hd); q_pos: (B, Tq); k_pos: (B, Tk).

    Returns (B, Tq, H, hd)."""
    B, Tq, H, hd = q.shape
    Tk, KV = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(block_q, Tq)
    bk = min(block_k, Tk)
    nq, nk = -(-Tq // bq), -(-Tk // bk)
    assert Tq % bq == 0 and Tk % bk == 0, "pad seq to block multiple"

    # head-major layouts so blocks are (tokens, hd) tiles
    qh = q.transpose(0, 2, 1, 3)                         # (B, H, Tq, hd)
    kh = k.transpose(0, 2, 1, 3)                         # (B, KV, Tk, hd)
    vh = v.transpose(0, 2, 1, 3)

    grid = (B, H, nq, nk)
    out = pl.pallas_call(
        functools.partial(_kernel, window=window, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, tq, tk: (b, h, tq, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, tq, tk: (b, h // G, tk, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, tq, tk: (b, h // G, tk, 0)),
            pl.BlockSpec((1, bq), lambda b, h, tq, tk: (b, tq)),
            pl.BlockSpec((1, bk), lambda b, h, tq, tk: (b, tk)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, tq, tk: (b, h, tq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Tq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh, q_pos.astype(jnp.int32), k_pos.astype(jnp.int32))
    return out.transpose(0, 2, 1, 3)
