"""CLI: `python -m repro.analysis [--report PATH] [--no-jaxpr] [--root DIR]`.

Runs every registered AST rule over the repo tree, applies the justified
allowlist, optionally runs the jaxpr trace audit for every family config,
and exits non-zero on any surviving finding or allowlist hygiene problem.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
from pathlib import Path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--report", metavar="PATH", default=None,
                    help="write the JSON report here (CI artifact)")
    ap.add_argument("--no-jaxpr", action="store_true",
                    help="skip the jaxpr trace audit (no jax import)")
    ap.add_argument("--root", default=None,
                    help="repo root (default: derived from this package)")
    args = ap.parse_args(argv)

    from repro.analysis import ALLOWLIST, Tree, run
    root = Path(args.root) if args.root else \
        Path(__file__).resolve().parents[3]
    tree = Tree(root=root)
    report = run(tree, allows=ALLOWLIST)

    if not args.no_jaxpr:
        from repro.analysis.jaxpr_audit import run_jaxpr_audit
        audited = run_jaxpr_audit()
        report.per_rule["jaxpr-audit"] = len(audited)
        report.findings.extend(audited)

    for f in report.problems:
        print(f"PROBLEM {f}", file=sys.stderr)
    for f in report.findings:
        print(f, file=sys.stderr)
    if args.report:
        Path(args.report).write_text(report.to_json())
    n_rules = len(report.per_rule)
    if report.ok:
        print(f"analysis OK: {n_rules} rules over "
              f"{report.checked_files} files, 0 findings")
        return 0
    print(f"analysis FAILED: {len(report.findings)} finding(s), "
          f"{len(report.problems)} allowlist problem(s)", file=sys.stderr)
    return 1


if __name__ == "__main__":
    sys.exit(main())
