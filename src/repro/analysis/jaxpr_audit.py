"""jaxpr trace auditor (DESIGN §13): jit each model family's serving steps
on tiny reduced configs and inspect the closed jaxpr.

Three audits per family config in `src/repro/configs/`:

* **dtype**: no float64/complex128 value anywhere in the traced serving
  step (inputs, constants, any equation output, recursively through
  sub-jaxprs). A stray f64 literal silently doubles KV bytes-per-token and
  halves every MemoryModel budget the scheduler trusts.
* **callback**: no `pure_callback` / `io_callback` / `debug_callback`
  primitive inside a jitted serving step — a callback is a hidden
  host-device sync point the host-sync lint cannot see (it hides behind
  jit), and the async dispatch-ahead loop (ROADMAP) cannot overlap it.
* **recompile**: tracing the decode step across the compiled
  `batch_buckets` shapes retraces exactly once per bucket — a step
  function that closes over drifting Python state retraces per call and
  turns every scheduling interval into a compile.

Imports jax lazily: the AST rules must stay importable (and fast) without
an accelerator stack.
"""
from __future__ import annotations

from typing import Iterable, List, Optional, Sequence, Tuple

from repro.analysis.framework import Finding

#: callback primitives banned inside jitted serving steps
CALLBACK_PRIMS = {"pure_callback", "io_callback", "debug_callback"}

#: dtypes banned anywhere in a serving-step jaxpr
BAD_DTYPES = {"float64", "complex128"}


def _sub_jaxprs(v) -> Iterable:
    """Jaxprs nested inside an eqn param (closed or open, possibly lists)."""
    import jax
    if isinstance(v, jax.core.ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, jax.core.Jaxpr):
        yield v
    elif isinstance(v, (list, tuple)):
        for item in v:
            yield from _sub_jaxprs(item)


def _iter_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                yield from _iter_eqns(sub)


def _audit_closed(closed, step: str, path: str) -> List[Finding]:
    """dtype + callback audit of one closed jaxpr."""
    out: List[Finding] = []
    seen_dtypes = set()
    for v in list(closed.jaxpr.invars) + list(closed.jaxpr.constvars):
        dt = getattr(v.aval, "dtype", None)
        if dt is not None and str(dt) in BAD_DTYPES:
            seen_dtypes.add(str(dt))
    callbacks = set()
    for eqn in _iter_eqns(closed.jaxpr):
        if eqn.primitive.name in CALLBACK_PRIMS:
            callbacks.add(eqn.primitive.name)
        for v in eqn.outvars:
            dt = getattr(getattr(v, "aval", None), "dtype", None)
            if dt is not None and str(dt) in BAD_DTYPES:
                seen_dtypes.add(str(dt))
    for dt in sorted(seen_dtypes):
        out.append(Finding(
            "jaxpr-audit", path, 1,
            f"{step}: {dt} value in the traced serving step — double-width "
            f"math silently breaks every MemoryModel byte budget"))
    for cb in sorted(callbacks):
        out.append(Finding(
            "jaxpr-audit", path, 1,
            f"{step}: {cb} primitive inside a jitted serving step — a "
            f"hidden host sync the async engine loop cannot overlap"))
    return out


def audit_arch(arch: str, buckets: Sequence[int] = (1, 2),
               max_context: int = 32, prefill_chunk: int = 8,
               recompile: bool = True) -> List[Finding]:
    """Run the full audit for one registry arch (reduced variant)."""
    import jax
    import jax.numpy as jnp

    from repro.config.registry import _ARCH_MODULES, get_config
    from repro.models.model import build_model, default_enc_len

    path = f"src/repro/configs/{_ARCH_MODULES[arch]}.py"
    cfg = get_config(arch, "reduced")
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    out: List[Finding] = []

    # decode step: one token against a max_context cache
    b = max(buckets)
    cache = model.init_cache(b, max_context)
    toks = jnp.zeros((b,), jnp.int32)
    lens = jnp.full((b,), -1, jnp.int32)
    closed = jax.make_jaxpr(model.decode_step)(params, toks, lens, cache)
    out.extend(_audit_closed(closed, f"{arch} decode_step", path))

    # chunked prefill (the engine's per-lane graph shape)
    T = prefill_chunk
    pcache = model.init_cache(1, max_context, prefill_chunk=T)
    tt = jnp.zeros((1, T), jnp.int32)
    pos = jnp.arange(T, dtype=jnp.int32)[None, :]
    enc_len = default_enc_len(cfg)
    extras = None
    if enc_len:
        key = "enc_frames" if cfg.family.value == "encdec" else "images"
        extras = {key: jnp.zeros((1, enc_len, cfg.d_model), jnp.float32)}
    closed = jax.make_jaxpr(
        lambda p, t, q, c: model.prefill(p, t, q, c, extras))(
            params, tt, pos, pcache)
    out.extend(_audit_closed(closed, f"{arch} prefill", path))

    # paged decode step (DESIGN §9): pools + block tables
    block_size = 16
    max_blocks = -(-max_context // block_size)
    num_blocks = b * max_blocks
    pgcache = model.init_paged_cache(b, num_blocks, block_size)
    tables = jnp.full((b, max_blocks), -1, jnp.int32)
    closed = jax.make_jaxpr(model.decode_step_paged)(
        params, toks, lens, tables, pgcache)
    out.extend(_audit_closed(closed, f"{arch} decode_step_paged", path))

    if recompile:
        traces = {"n": 0}

        def step(p, t, l, c):
            traces["n"] += 1
            return model.decode_step(p, t, l, c)

        jf = jax.jit(step)
        for bb in buckets:
            bcache = model.init_cache(bb, max_context)
            bt = jnp.zeros((bb,), jnp.int32)
            bl = jnp.full((bb,), -1, jnp.int32)
            for _ in range(2):   # second call must hit the jit cache
                _, bcache = jf(params, bt, bl, bcache)
        if traces["n"] != len(buckets):
            out.append(Finding(
                "jaxpr-audit", path, 1,
                f"{arch} decode_step retraced {traces['n']}x across "
                f"{len(buckets)} batch buckets — expected exactly one "
                f"trace per bucket shape (a retrace per call turns every "
                f"scheduling interval into a compile)"))
    return out


def run_jaxpr_audit(archs: Optional[Sequence[str]] = None,
                    recompile: bool = True) -> List[Finding]:
    from repro.config.registry import list_archs
    out: List[Finding] = []
    for arch in (archs if archs is not None else list_archs()):
        out.extend(audit_arch(arch, recompile=recompile))
    return out
