"""Flight-rules static analysis framework (DESIGN §13).

Pure-stdlib (`ast`) lint infrastructure for the repo-specific invariants no
generic linter knows about: every rule codifies a past bug family (PR 2's
allocator drift, PR 3's controller feedback) or a pending refactor
precondition (the async dispatch-ahead engine's host-sync work-list).

Three pieces:

* `Tree` — the file set under analysis. Conventional anchor paths
  (engine/sim/config/serve CLI/docs) are overridable so rule tests can point
  at miniature fixture trees under `tests/fixtures/analysis/`.
* rules — functions `rule(tree) -> [Finding]` registered by id via `@rule`.
  AST rules live in `rules_ast.py`, cross-file structural rules in
  `rules_repo.py`, the trace auditor in `jaxpr_audit.py`.
* the allowlist — `Allow` entries with an ENFORCED justification: a finding
  is only suppressed by an entry carrying a real reason (>= MIN_REASON
  chars) whose (rule, path, scope) matches EXACTLY `count` findings. Fewer
  matches = the entry is stale (the code it excused is gone); more = a new
  un-reviewed site is hiding behind an old excuse. Both fail the run, which
  makes the allowlist a live work-list — e.g. the engine's host-sync
  entries enumerate exactly the sync points the async loop must remove.
"""
from __future__ import annotations

import ast
import dataclasses
import json
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Tuple

#: minimum justification length — long enough to force a real sentence
MIN_REASON = 20


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str            # rule id ("host-sync", "counter-parity", ...)
    path: str            # repo-relative posix path
    line: int            # 1-indexed anchor line
    message: str
    scope: str = ""      # enclosing qualified def ("Engine.warmup"); "" = module

    @property
    def anchor(self) -> str:
        return f"{self.path}:{self.line}"

    def __str__(self) -> str:
        return f"{self.anchor} [{self.rule}] {self.message}"


@dataclasses.dataclass(frozen=True)
class Allow:
    """One justified suppression. `scope` is the qualified enclosing
    function ("" = anywhere in the file); `count` is the EXACT number of
    findings the entry absorbs — a mismatch in either direction fails."""
    rule: str
    path: str
    scope: str
    count: int
    reason: str


@dataclasses.dataclass
class Tree:
    """The file set a run analyses, plus the conventional anchor files the
    cross-file rules read. Fixture trees override the root only — the
    relative anchors are part of the repo contract."""
    root: Path
    engine: str = "src/repro/serving/engine.py"
    sim: str = "src/repro/serving/sim.py"
    kv_cache: str = "src/repro/serving/kv_cache.py"
    config: str = "src/repro/config/base.py"
    serve_cli: str = "src/repro/launch/serve.py"
    readme: str = "README.md"
    docs_dir: str = "docs"
    scan_dirs: Tuple[str, ...] = ("src", "tests")
    # the rule-test corpus is deliberately full of violations
    exclude: Tuple[str, ...] = ("tests/fixtures/",)

    def __post_init__(self):
        self.root = Path(self.root)
        self._ast_cache: Dict[str, ast.Module] = {}

    def rel(self, p: Path) -> str:
        return p.relative_to(self.root).as_posix()

    def files(self) -> List[Path]:
        out: List[Path] = []
        for d in self.scan_dirs:
            base = self.root / d
            if not base.exists():
                continue
            for p in sorted(base.rglob("*.py")):
                rp = self.rel(p)
                if any(rp.startswith(e) or f"/{e}" in rp for e in self.exclude):
                    continue
                out.append(p)
        return out

    def read(self, relpath: str) -> Optional[str]:
        p = self.root / relpath
        return p.read_text() if p.exists() else None

    def parse(self, relpath: str) -> Optional[ast.Module]:
        if relpath not in self._ast_cache:
            text = self.read(relpath)
            self._ast_cache[relpath] = \
                ast.parse(text, filename=relpath) if text is not None else None
        return self._ast_cache[relpath]

    def doc_text(self) -> str:
        """README + every docs/*.md, lowercased with dashes normalized to
        underscores — the config-wiring rule's documentation corpus."""
        parts = []
        for relpath in [self.readme]:
            t = self.read(relpath)
            if t:
                parts.append(t)
        docs = self.root / self.docs_dir
        if docs.exists():
            for p in sorted(docs.glob("*.md")):
                parts.append(p.read_text())
        return "\n".join(parts).lower().replace("-", "_")


# -- rule registry -----------------------------------------------------------

RULES: Dict[str, Callable[[Tree], List[Finding]]] = {}


def rule(rule_id: str):
    def deco(fn):
        RULES[rule_id] = fn
        fn.rule_id = rule_id
        return fn
    return deco


# -- AST helpers shared by rules ---------------------------------------------

def qualified_scopes(mod: ast.Module) -> Dict[ast.AST, str]:
    """Map every node to its qualified enclosing def ("Cls.meth")."""
    scopes: Dict[ast.AST, str] = {}

    def walk(node: ast.AST, scope: str):
        for child in ast.iter_child_nodes(node):
            s = scope
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                s = f"{scope}.{child.name}" if scope else child.name
            scopes[child] = s
            walk(child, s)
    walk(mod, "")
    return scopes


def dotted_name(node: ast.AST) -> str:
    """'jax.block_until_ready' for Attribute/Name chains, '' otherwise."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def attr_chain(node: ast.AST) -> List[str]:
    """Attribute names along a value chain, subscripts transparent:
    `self.blocks.tables[rid].append` -> ['append', 'tables', 'blocks']."""
    out: List[str] = []
    while True:
        if isinstance(node, ast.Attribute):
            out.append(node.attr)
            node = node.value
        elif isinstance(node, ast.Subscript):
            node = node.value
        elif isinstance(node, ast.Call):
            node = node.func
        else:
            return out


# -- allowlist application ---------------------------------------------------

def apply_allowlist(findings: Sequence[Finding], allows: Sequence[Allow]
                    ) -> Tuple[List[Finding], List[Finding]]:
    """Returns (surviving findings, allowlist problems). An entry suppresses
    its matches only when justified AND matching exactly `count` findings."""
    problems: List[Finding] = []
    suppressed: set = set()
    for a in allows:
        where = f"{a.rule} @ {a.path}" + (f":{a.scope}" if a.scope else "")
        if len(a.reason.strip()) < MIN_REASON:
            problems.append(Finding(
                "allowlist", a.path, 0,
                f"unjustified allowlist entry ({where}): reason must be a "
                f"real sentence (>= {MIN_REASON} chars), got {a.reason!r}"))
            continue
        matched = [i for i, f in enumerate(findings)
                   if f.rule == a.rule and f.path == a.path
                   and (not a.scope or f.scope == a.scope)
                   and i not in suppressed]
        if len(matched) == a.count:
            suppressed.update(matched)
        elif not matched:
            problems.append(Finding(
                "allowlist", a.path, 0,
                f"stale allowlist entry ({where}): matches no finding — the "
                f"code it excused is gone; delete the entry"))
        else:
            problems.append(Finding(
                "allowlist", a.path, 0,
                f"allowlist count drift ({where}): entry declares "
                f"{a.count} finding(s) but {len(matched)} match — "
                f"re-review the site and update the count"))
    kept = [f for i, f in enumerate(findings) if i not in suppressed]
    return kept, problems


# -- runner ------------------------------------------------------------------

@dataclasses.dataclass
class Report:
    findings: List[Finding]
    problems: List[Finding]     # allowlist hygiene failures
    checked_files: int
    per_rule: Dict[str, int]

    @property
    def ok(self) -> bool:
        return not self.findings and not self.problems

    def to_json(self) -> str:
        return json.dumps({
            "ok": self.ok,
            "checked_files": self.checked_files,
            "per_rule": self.per_rule,
            "findings": [dataclasses.asdict(f) for f in self.findings],
            "allowlist_problems": [dataclasses.asdict(f)
                                   for f in self.problems],
        }, indent=2)


def run(tree: Tree, rule_ids: Optional[Sequence[str]] = None,
        allows: Sequence[Allow] = ()) -> Report:
    ids = list(rule_ids) if rule_ids is not None else sorted(RULES)
    raw: List[Finding] = []
    per_rule: Dict[str, int] = {}
    for rid in ids:
        found = RULES[rid](tree)
        per_rule[rid] = len(found)
        raw.extend(found)
    kept, problems = apply_allowlist(raw, allows)
    kept.sort(key=lambda f: (f.path, f.line, f.rule))
    return Report(kept, problems, len(tree.files()), per_rule)
