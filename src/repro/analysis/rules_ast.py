"""AST lint rules over the serving stack (DESIGN §13).

host-sync
    `jax.block_until_ready` / `jax.device_get` / `.item()` /
    `np.asarray`-on-a-device-value force a host<->device synchronization.
    Inside `src/repro/serving/` every such site sits on the scheduling
    critical path — the paper's step-overhead term and the host-side stalls
    "Mind the Memory Gap" measures — so each one must be an allowlisted,
    justified sync point. The allowlist IS the work-list for the async
    dispatch-ahead engine loop (ROADMAP item 1): overlapping interval N+1's
    admission with interval N's device step means deleting these entries
    one by one.

allocator-encapsulation
    BlockManager's refcounts, free lists, block tables, prefix index and
    swap ledgers may only be mutated inside `kv_cache.py`. The PR 2
    allocator-drift bug family (state-only leaks, failed-grow drift) was
    exactly out-of-band mutation of this state; reads are fine, writes
    anywhere else are structurally banned.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.framework import (Finding, Tree, attr_chain, dotted_name,
                                      qualified_scopes, rule)

# -- host-sync ---------------------------------------------------------------

#: dotted callables that force a device->host sync
_SYNC_CALLS = {
    "jax.block_until_ready": "jax.block_until_ready",
    "jax.device_get": "jax.device_get",
}

#: numpy coercions that pull a device array to host when fed one
_NP_COERCE = {"np.asarray", "np.array", "numpy.asarray", "numpy.array"}

#: argument node types that are host-side literals, not device values
_HOST_LITERAL = (ast.List, ast.Tuple, ast.ListComp, ast.Constant,
                 ast.GeneratorExp)


@rule("host-sync")
def check_host_sync(tree: Tree) -> List[Finding]:
    out: List[Finding] = []
    for p in tree.files():
        rp = tree.rel(p)
        if "/serving/" not in f"/{rp}" or not rp.startswith("src/"):
            continue
        mod = tree.parse(rp)
        if mod is None:
            continue
        scopes = qualified_scopes(mod)
        for node in ast.walk(mod):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            msg = ""
            if name in _SYNC_CALLS:
                msg = (f"host-device sync point: {name}() blocks the "
                       f"scheduler on the device — allowlist with a "
                       f"justification or move off the critical path")
            elif name in _NP_COERCE and node.args \
                    and not isinstance(node.args[0], _HOST_LITERAL):
                msg = (f"{name}() on a non-literal operand copies a device "
                       f"array to host (an implicit sync) — use host data "
                       f"or allowlist with a justification")
            elif isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "item" and not node.args:
                msg = (".item() pulls a device scalar to host (an implicit "
                       "sync) — batch the readback or allowlist it")
            if msg:
                out.append(Finding("host-sync", rp, node.lineno, msg,
                                   scope=scopes.get(node, "")))
    return out


# -- allocator-encapsulation -------------------------------------------------

#: BlockManager state only kv_cache.py may mutate (DESIGN §9/§10/§11)
_PROTECTED = {"tables", "swapped_tables", "ref", "_free", "_swap_free",
              "_cached", "_index", "_hash_of", "_commit", "_released",
              "_deferred", "_epoch_open", "_shadow_snap"}

#: container methods that mutate their receiver
_MUTATORS = {"append", "extend", "insert", "pop", "remove", "clear",
             "setdefault", "update", "popitem"}


def _protected_target(node: ast.AST) -> str:
    """The protected attribute a store/del target reaches, or ''."""
    if isinstance(node, ast.Subscript):
        node = node.value
    if isinstance(node, ast.Attribute) and node.attr in _PROTECTED:
        return node.attr
    return ""


@rule("allocator-encapsulation")
def check_allocator_encapsulation(tree: Tree) -> List[Finding]:
    out: List[Finding] = []
    for p in tree.files():
        rp = tree.rel(p)
        if rp == tree.kv_cache:
            continue
        mod = tree.parse(rp)
        if mod is None:
            continue
        scopes = qualified_scopes(mod)

        def flag(node, attr, how):
            out.append(Finding(
                "allocator-encapsulation", rp, node.lineno,
                f"mutation of BlockManager.{attr} ({how}) outside "
                f"kv_cache.py — allocator state changes only through "
                f"BlockManager methods (the PR 2 drift-family guard)",
                scope=scopes.get(node, "")))

        for node in ast.walk(mod):
            if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for t in targets:
                    attr = _protected_target(t)
                    if attr:
                        flag(node, attr, "assignment")
            elif isinstance(node, ast.Delete):
                for t in node.targets:
                    attr = _protected_target(t)
                    if attr:
                        flag(node, attr, "del")
            elif isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _MUTATORS:
                chain = attr_chain(node.func)
                hit = next((a for a in chain[1:] if a in _PROTECTED), "")
                if hit:
                    flag(node, hit, f".{node.func.attr}()")
    return out
