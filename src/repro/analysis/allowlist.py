"""The justified allowlist (DESIGN §13).

Every entry suppresses EXACTLY `count` findings of `rule` in `path`
(optionally narrowed to one qualified `scope`) and must carry a real
justification — the framework rejects reasons under MIN_REASON chars,
entries matching no finding (stale), and entries matching a different
number of findings than declared (count drift: a new un-reviewed site
hiding behind an old excuse).

The host-sync entries below are deliberate. The async dispatch-ahead loop
(DESIGN §14, ROADMAP item 1) drained the per-graph fences the synchronous
loop carried in _advance_prefill (4) and _decode_once (2): dispatch is now
fence-free and exactly one blocking pair remains on the serving path — the
retirement fence + bulk token readback in Engine._retire_step. warmup's
blocks are pre-serving by construction; _swap_out's device_get IS the swap
transfer. Total on-path syncs: 2 (was 6), whole file: 8 (was 12).
"""
from __future__ import annotations

from typing import List

from repro.analysis.framework import Allow

ENGINE = "src/repro/serving/engine.py"

ALLOWLIST: List[Allow] = [
    Allow("host-sync", ENGINE, "Engine.warmup", 5,
          "warmup deliberately blocks on each compiled graph so first-token "
          "latency is never paid mid-benchmark; off the serving path"),
    Allow("host-sync", ENGINE, "Engine._retire_step", 2,
          "THE pipeline fence (DESIGN SS14): one block_until_ready per "
          "retired interval — the measured step_device_s — then one bulk "
          "device_get of every sampled/first token; the only blocking "
          "pair the async dispatch-ahead loop retains on the serving path"),
    Allow("host-sync", ENGINE, "Engine._swap_out", 1,
          "device_get of evicted KV rows is the swap transfer itself "
          "(DESIGN SS11); it must complete before the rows are reused"),
    Allow("counter-parity", ENGINE, "Engine.summary", 2,
          "copy_rows/copy_bytes count physical tensor row moves during "
          "defrag; the sim has no tensor storage, so no twin can exist"),
]
