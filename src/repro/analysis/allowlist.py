"""The justified allowlist (DESIGN §13).

Every entry suppresses EXACTLY `count` findings of `rule` in `path`
(optionally narrowed to one qualified `scope`) and must carry a real
justification — the framework rejects reasons under MIN_REASON chars,
entries matching no finding (stale), and entries matching a different
number of findings than declared (count drift: a new un-reviewed site
hiding behind an old excuse).

The host-sync entries below are deliberate: they enumerate the synchronous
engine loop's blocking readbacks, i.e. the exact work-list the async
dispatch-ahead refactor (ROADMAP item 1) must drain. Shrink the counts as
sites are removed — the linter will hold you to it.
"""
from __future__ import annotations

from typing import List

from repro.analysis.framework import Allow

ENGINE = "src/repro/serving/engine.py"

ALLOWLIST: List[Allow] = [
    Allow("host-sync", ENGINE, "Engine.warmup", 5,
          "warmup deliberately blocks on each compiled graph so first-token "
          "latency is never paid mid-benchmark; off the serving path"),
    Allow("host-sync", ENGINE, "Engine._advance_prefill", 4,
          "synchronous loop blocks on the prefill chunk and pulls last-token "
          "logits to host for sampling; async loop work-list (ROADMAP 1)"),
    Allow("host-sync", ENGINE, "Engine._decode_once", 2,
          "synchronous loop blocks on the decode step and pulls sampled "
          "tokens to host for stop checks; async loop work-list (ROADMAP 1)"),
    Allow("host-sync", ENGINE, "Engine._swap_out", 1,
          "device_get of evicted KV rows is the swap transfer itself "
          "(DESIGN SS11); it must complete before the rows are reused"),
    Allow("counter-parity", ENGINE, "Engine.summary", 2,
          "copy_rows/copy_bytes count physical tensor row moves during "
          "defrag; the sim has no tensor storage, so no twin can exist"),
]
