"""Cross-file structural rules (DESIGN §13).

counter-parity
    Every scalar key `Engine.summary()` returns must have a same-named
    `SimResult` field (or property), and vice versa. The engine/sim
    differential harness (`tests/test_differential.py`, DESIGN §7) compares
    the twins counter by counter — a counter that exists on one side only
    silently escapes the parity net. List-valued SimResult fields (traces,
    decision logs) are structurally exempt: they are not scalar counters.

config-wiring
    Every `ServeConfig` field must be (a) read somewhere in `src/` — a
    field nothing consumes is dead weight masquerading as a knob; (b)
    wired through the serving CLI (`launch/serve.py` passes it as a
    `ServeConfig(...)` keyword) so operators can actually turn it; and (c)
    named in README/docs so `test_docs`'s flag-table gate has something to
    anchor. This is the AST generalization of `test_docs`' string checks:
    it catches the knob that parses but never reaches the scheduler.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.framework import Finding, Tree, rule


def _find_class(mod: ast.Module, name: str) -> Optional[ast.ClassDef]:
    for node in ast.walk(mod):
        if isinstance(node, ast.ClassDef) and node.name == name:
            return node
    return None


# -- counter-parity ----------------------------------------------------------

def _summary_keys(cls: ast.ClassDef) -> Dict[str, int]:
    """String keys of the dict literal(s) `summary()` returns -> lineno."""
    out: Dict[str, int] = {}
    for node in cls.body:
        if isinstance(node, ast.FunctionDef) and node.name == "summary":
            for ret in ast.walk(node):
                if isinstance(ret, ast.Return) \
                        and isinstance(ret.value, ast.Dict):
                    for k in ret.value.keys:
                        if isinstance(k, ast.Constant) \
                                and isinstance(k.value, str):
                            out[k.value] = k.lineno
    return out


def _scalar_fields(cls: ast.ClassDef) -> Dict[str, int]:
    """SimResult scalar counters: annotated fields (lists exempt) plus
    @property accessors -> lineno."""
    out: Dict[str, int] = {}
    for node in cls.body:
        if isinstance(node, ast.AnnAssign) \
                and isinstance(node.target, ast.Name):
            ann = ast.unparse(node.annotation)
            if re.search(r"\b(List|Dict|list|dict)\b", ann):
                continue
            out[node.target.id] = node.lineno
        elif isinstance(node, ast.FunctionDef):
            decos = {ast.unparse(d) for d in node.decorator_list}
            if "property" in decos:
                out[node.name] = node.lineno
    return out


@rule("counter-parity")
def check_counter_parity(tree: Tree) -> List[Finding]:
    out: List[Finding] = []
    eng_mod, sim_mod = tree.parse(tree.engine), tree.parse(tree.sim)
    if eng_mod is None or sim_mod is None:
        return out
    eng_cls = _find_class(eng_mod, "Engine")
    sim_cls = _find_class(sim_mod, "SimResult")
    if eng_cls is None or sim_cls is None:
        return out
    keys = _summary_keys(eng_cls)
    fields = _scalar_fields(sim_cls)
    for k in sorted(set(keys) - set(fields)):
        out.append(Finding(
            "counter-parity", tree.engine, keys[k],
            f"Engine.summary() key '{k}' has no SimResult twin — the "
            f"differential harness cannot compare it (add the field to "
            f"SimResult or justify an engine-only counter)",
            scope="Engine.summary"))
    for k in sorted(set(fields) - set(keys)):
        out.append(Finding(
            "counter-parity", tree.sim, fields[k],
            f"SimResult scalar '{k}' has no Engine.summary() key — the "
            f"differential harness cannot compare it (surface it in "
            f"summary() or justify a sim-only counter)",
            scope="SimResult"))
    return out


# -- config-wiring -----------------------------------------------------------

def _serveconfig_fields(tree: Tree) -> Dict[str, int]:
    mod = tree.parse(tree.config)
    if mod is None:
        return {}
    cls = _find_class(mod, "ServeConfig")
    if cls is None:
        return {}
    return {node.target.id: node.lineno for node in cls.body
            if isinstance(node, ast.AnnAssign)
            and isinstance(node.target, ast.Name)}


def _attribute_reads(tree: Tree, skip: Tuple[str, ...]) -> Set[str]:
    """Every attribute name read anywhere under src/ (minus `skip`)."""
    reads: Set[str] = set()
    for p in tree.files():
        rp = tree.rel(p)
        if not rp.startswith("src/") or rp in skip:
            continue
        mod = tree.parse(rp)
        if mod is None:
            continue
        for node in ast.walk(mod):
            if isinstance(node, ast.Attribute) \
                    and isinstance(node.ctx, ast.Load):
                reads.add(node.attr)
    return reads


def _cli_wired_fields(tree: Tree) -> Set[str]:
    """Keywords of every ServeConfig(...) call in launch/serve.py."""
    mod = tree.parse(tree.serve_cli)
    wired: Set[str] = set()
    if mod is None:
        return wired
    for node in ast.walk(mod):
        if isinstance(node, ast.Call):
            fn = node.func
            name = fn.id if isinstance(fn, ast.Name) else \
                fn.attr if isinstance(fn, ast.Attribute) else ""
            if name == "ServeConfig":
                wired.update(kw.arg for kw in node.keywords if kw.arg)
    return wired


@rule("config-wiring")
def check_config_wiring(tree: Tree) -> List[Finding]:
    fields = _serveconfig_fields(tree)
    if not fields:
        return []
    reads = _attribute_reads(tree, skip=(tree.config,))
    wired = _cli_wired_fields(tree)
    docs = tree.doc_text()
    out: List[Finding] = []
    for f, line in sorted(fields.items()):
        if f not in reads:
            out.append(Finding(
                "config-wiring", tree.config, line,
                f"dead ServeConfig field '{f}': nothing under src/ reads "
                f"it — wire it into the engine/sim or delete it"))
            continue  # dead fields need no CLI flag or doc row
        if f not in wired:
            out.append(Finding(
                "config-wiring", tree.config, line,
                f"ServeConfig field '{f}' is not wired through the serving "
                f"CLI: launch/serve.py never passes it to ServeConfig(...) "
                f"— operators cannot turn this knob"))
        if f not in docs:
            out.append(Finding(
                "config-wiring", tree.config, line,
                f"ServeConfig field '{f}' is undocumented: name it in the "
                f"README or docs/ (dashes and case are normalized)"))
    return out
