"""Flight-rules static analysis (DESIGN §13): repo-specific AST lint rules
plus a jaxpr trace auditor, run via `python -m repro.analysis`."""
from repro.analysis.framework import (Allow, Finding, Report, Tree, RULES,
                                      apply_allowlist, rule, run)
from repro.analysis import rules_ast, rules_repo  # noqa: F401  (register rules)
from repro.analysis.allowlist import ALLOWLIST

__all__ = ["Allow", "Finding", "Report", "Tree", "RULES", "ALLOWLIST",
           "apply_allowlist", "rule", "run"]
