import os
os.environ["XLA_FLAGS"] = os.environ.get("REPRO_DRYRUN_XLA_FLAGS",
                                         "--xla_force_host_platform_device_count=512")
# ^ MUST be the very first two lines — before ANY other import (jax locks
# the device count on first init). The dry-run, and ONLY the dry-run, needs
# 512 placeholder host devices; smoke tests and benches see 1 device.
#
# Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.config.base import INPUT_SHAPES, ArchFamily, InputShape, \
    ModelConfig, TrainConfig  # noqa: E402
from repro.config.registry import get_config, list_archs  # noqa: E402
from repro.distributed.sharding import (batch_shardings, cache_shardings,  # noqa: E402
                                        decode_input_shardings,
                                        param_shardings, replicated)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.model import Model, default_enc_len, input_specs  # noqa: E402
from repro.training.optimizer import adamw_init  # noqa: E402
from repro.training.train_loop import make_train_step  # noqa: E402

# ---------------------------------------------------------------------------
# long_500k applicability (DESIGN §4): sub-quadratic state only

LONG_OK = {
    "mamba2-2.7b": "constant SSM state",
    "recurrentgemma-9b": "RG-LRU + 2048-window ring cache",
    "mistral-nemo-12b": "sliding-window variant (window 4096)",
}

# decode shapes exercised for every arch (all have decoders; seamless-m4t's
# decode runs its decoder with a fixed cross-KV — encoder itself has no
# decode step)


def resolve_config(arch: str, shape: InputShape) -> Optional[ModelConfig]:
    if shape.name == "long_500k":
        if arch not in LONG_OK:
            return None
        if arch == "mistral-nemo-12b":
            from repro.configs.mistral_nemo_12b import sliding
            return sliding(4096)
    cfg = get_config(arch)
    if cfg.moe is not None and shape.kind != "train":
        # production serving: capacity-factor dispatch, not the exact
        # worst-case no-drop used by the bitwise CPU engine (§Perf iter G)
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, inference_no_drop=False, capacity_factor=2.0))
    return cfg


# ---------------------------------------------------------------------------
# collective-bytes parser (post-SPMD optimized HLO)

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16}
_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    out = {op: 0 for op in _COLL_OPS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        for op in _COLL_OPS:
            idx = line.find(f" {op}(")
            if idx < 0:  # async form: count the -start, skip the -done
                idx = line.find(f" {op}-start(")
            if idx < 0:
                continue
            lhs = line[:idx]
            if "=" not in lhs:
                continue
            nbytes = 0
            for dt, dims in _SHAPE_RE.findall(lhs.split("=", 1)[1]):
                if dt not in _DTYPE_BYTES:
                    continue
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                nbytes += n * _DTYPE_BYTES[dt]
            out[op] += nbytes
            out["count"] += 1
            break
    return out


# ---------------------------------------------------------------------------
# lowering


def build_lowerable(arch: str, shape: InputShape, mesh):
    """Returns (fn, args, in_shardings, out_shardings, meta)."""
    cfg = resolve_config(arch, shape)
    if cfg is None:
        return None
    model = Model(cfg, dtype=jnp.bfloat16)
    specs = input_specs(cfg, shape)
    pshapes = model.init_shapes()
    pshard = param_shardings(pshapes, cfg, mesh)
    meta = {"params": cfg.param_count(), "active_params": cfg.active_param_count()}

    if shape.kind == "train":
        tcfg = TrainConfig(global_batch=shape.global_batch,
                           seq_len=shape.seq_len, remat=True)
        oshapes = jax.eval_shape(adamw_init, pshapes)
        oshard = param_shardings_opt(oshapes, pshard, mesh)
        bshard = batch_shardings(specs, cfg, mesh)
        fn = make_train_step(model, tcfg)
        args = (pshapes, oshapes, specs)
        in_sh = (pshard, oshard, bshard)
        out_sh = (pshard, oshard, None)
        return fn, args, in_sh, out_sh, meta

    # Cache sharding: GSPMD auto-inference (cache_mode="auto") finds
    # partial-axis layouts (e.g. kv-heads x half-model + replication) that
    # PartitionSpec cannot express; the explicit rules forced involuntary
    # remats and 16x more all-gather volume on GQA decode. Explicit specs
    # are kept for ablation (EXPERIMENTS §Perf).
    seq_shard = shape.global_batch == 1
    if os.environ.get("REPRO_CACHE_SHARDING", "auto") == "explicit":
        cache_sh = cache_shardings(specs["cache"], cfg, mesh,
                                   seq_shard=seq_shard)
    else:
        cache_sh = None

    if shape.kind == "prefill":
        tp = batch_shardings({"tokens": specs["tokens"],
                              "positions": specs["positions"]}, cfg, mesh)
        extras = specs.get("extras")
        ex_sh = batch_shardings(extras, cfg, mesh) if extras else None

        def prefill_fn(params, tokens, positions, cache, extras):
            # production serving: only the final position's logits are
            # needed to start decode (§Perf iteration A)
            return model.prefill(params, tokens, positions, cache, extras,
                                 last_only=True)

        args = (pshapes, specs["tokens"], specs["positions"], specs["cache"],
                extras)
        in_sh = (pshard, tp["tokens"], tp["positions"], cache_sh, ex_sh)
        return prefill_fn, args, in_sh, None, meta

    # decode: ONE token against a seq_len-deep cache. The cache is DONATED
    # (in-place update) as in any production serving loop (§Perf iter D).
    tok_sh = decode_input_shardings(cfg, mesh, shape.global_batch)

    def serve_step(params, tokens, seq_lens, cache):
        logits, cache = model.decode_step(params, tokens, seq_lens, cache)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    args = (pshapes, specs["tokens"], specs["seq_lens"], specs["cache"])
    in_sh = (pshard, tok_sh, tok_sh, cache_sh)
    meta["donate"] = (3,)
    return serve_step, args, in_sh, None, meta


def param_shardings_opt(oshapes, pshard, mesh):
    """Optimizer state shards like its parameter; scalars replicated."""
    return {
        "m": pshard, "v": pshard,
        "step": replicated(mesh),
    }


def run_one(arch: str, shape_name: str, multi_pod: bool) -> Dict[str, Any]:
    shape = INPUT_SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "status": "ok"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    built = build_lowerable(arch, shape, mesh)
    if built is None:
        rec["status"] = "skipped"
        rec["reason"] = "long_500k requires sub-quadratic attention (DESIGN §4)"
        return rec
    fn, args, in_sh, out_sh, meta = built
    donate = meta.pop("donate", ())
    rec.update(meta)
    try:
        t0 = time.perf_counter()
        # jax.set_mesh (not the legacy `with mesh:`) so model-level
        # with_sharding_constraint hints see the abstract mesh
        with jax.set_mesh(mesh):
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            rec["lower_s"] = round(time.perf_counter() - t0, 2)
            t1 = time.perf_counter()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.perf_counter() - t1, 2)
        try:
            ma = compiled.memory_analysis()
            if ma is not None:
                for f in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes"):
                    v = getattr(ma, f, None)
                    if v is not None:
                        rec[f] = int(v)
        except Exception as e:  # CPU backend may not implement it
            rec["memory_analysis_error"] = str(e)
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            if ca:
                rec["flops"] = float(ca.get("flops", -1))
                rec["bytes_accessed"] = float(ca.get("bytes accessed", -1))
        except Exception as e:
            rec["cost_analysis_error"] = str(e)
        try:
            rec["collectives"] = collective_bytes(compiled.as_text())
        except Exception as e:
            rec["collectives_error"] = str(e)
    except Exception:
        rec["status"] = "error"
        rec["error"] = traceback.format_exc()[-2000:]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    args = ap.parse_args()

    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("status") in ("ok", "skipped"):
                        done.add((r["arch"], r["shape"], r["mesh"]))
                except json.JSONDecodeError:
                    pass

    with open(args.out, "a") as f:
        for arch in archs:
            for shape in shapes:
                for mp in meshes:
                    meshn = "2x16x16" if mp else "16x16"
                    if (arch, shape, meshn) in done:
                        print(f"skip (cached): {arch} {shape} {meshn}")
                        continue
                    print(f"=== {arch} x {shape} x {meshn}", flush=True)
                    rec = run_one(arch, shape, mp)
                    f.write(json.dumps(rec) + "\n")
                    f.flush()
                    msg = rec["status"]
                    if rec["status"] == "ok":
                        msg += (f" lower={rec.get('lower_s')}s"
                                f" compile={rec.get('compile_s')}s"
                                f" flops={rec.get('flops', 0):.3g}"
                                f" coll={rec.get('collectives', {})}")
                    elif rec["status"] == "error":
                        msg += "\n" + rec["error"][-500:]
                    print(msg, flush=True)


if __name__ == "__main__":
    main()
