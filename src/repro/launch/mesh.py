"""Production mesh construction (function, not module constant — importing
this module never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=types)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count
    set before jax init)."""
    types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=types)
