"""Production mesh construction (function, not module constant — importing
this module never touches jax device state)."""
from __future__ import annotations

import os
import sys


def _make_mesh(shape, axes):
    """jax.make_mesh across jax versions: axis_types (Auto) only exists on
    newer jax; older versions take (shape, axis_names) alone."""
    import jax

    at = getattr(jax.sharding, "AxisType", None)
    if at is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(at.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count
    set before jax init — see `ensure_cpu_devices`)."""
    return _make_mesh(shape, axes)


def make_serving_mesh(mesh_shape):
    """Mesh for `ServeConfig.mesh_shape` (DESIGN §12): last axis is
    "model" (tensor parallelism), leading axes ("data",) or
    ("pod", "data")."""
    shape = tuple(mesh_shape)
    axes = ("pod", "data", "model")[-len(shape):]
    return _make_mesh(shape, axes)


def ensure_cpu_devices(n: int) -> bool:
    """Ask XLA's host platform for >= n devices (CPU test meshes,
    DESIGN §12). Must run BEFORE jax initializes; returns False (and
    changes nothing) when jax is already imported or the flag is already
    set — callers on real accelerators are unaffected (the flag only
    applies to the host platform)."""
    flag = "--xla_force_host_platform_device_count"
    current = os.environ.get("XLA_FLAGS", "")
    if "jax" in sys.modules or flag in current:
        return False
    os.environ["XLA_FLAGS"] = f"{current} {flag}={n}".strip()
    return True
