"""Serving CLI: run the continuous-batching engine on any --arch (reduced
variants on CPU; the same engine is the production template for TPU).

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \
        --policy combined --sla-ms 200 --requests 20

Every flag below is documented in the README's "Serving CLI flags" table;
`tests/test_docs.py` fails if a flag is added here without a table row.

jax is imported only AFTER argument parsing: `--mesh` (DESIGN §12) must be
able to provision forced host devices for CPU test meshes, which XLA reads
at first jax init.
"""
from __future__ import annotations

import argparse

from repro.config.base import ServeConfig
from repro.config.registry import get_config, list_archs
from repro.launch.mesh import ensure_cpu_devices
from repro.serving.cost_model import PROFILES


def parse_buckets(spec: str):
    """"1,2,4" -> (1, 2, 4): compiled decode batch bucket sizes."""
    try:
        shape = tuple(int(p) for p in spec.split(",") if p)
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"--batch-buckets wants comma-separated ints, got {spec!r}")
    if any(s < 1 for s in shape):
        raise argparse.ArgumentTypeError(
            f"--batch-buckets sizes must be >= 1, got {spec!r}")
    return shape


def parse_mesh(spec: str):
    """"2,2" / "2x2" -> (2, 2); last axis is "model" (DESIGN §12)."""
    parts = [p for p in spec.replace("x", ",").split(",") if p]
    shape = tuple(int(p) for p in parts)
    if not shape or any(s < 1 for s in shape) or len(shape) > 3:
        raise argparse.ArgumentTypeError(
            f"--mesh wants 1-3 comma-separated sizes (data,model), got {spec!r}")
    return shape


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=list_archs())
    ap.add_argument("--variant", default="reduced", choices=["reduced", "full"])
    ap.add_argument("--policy", default="memory",
                    choices=["static", "memory", "sla", "combined"])
    ap.add_argument("--sla-ms", type=float, default=0.0)
    ap.add_argument("--b-max", type=int, default=16)
    ap.add_argument("--b-min", type=int, default=1,
                    help="Alg 1 lower batch bound B_min")
    # controller tolerance bands + Alg 2 window control (paper §III)
    ap.add_argument("--eps-d", type=float, default=2.0, metavar="MS",
                    help="SLA latency tolerance band eps_D (ms)")
    ap.add_argument("--eps-m", type=float, default=0.05,
                    help="memory-overflow probability budget eps_M")
    ap.add_argument("--alpha", type=int, default=16,
                    help="Alg 2 window-width control alpha")
    ap.add_argument("--delta", type=int, default=4,
                    help="Alg 2 anti-noise relaxation delta")
    ap.add_argument("--l0-refresh", type=int, default=32, metavar="N",
                    help="L0 offline refresh cadence in controller intervals")
    ap.add_argument("--block-size", type=int, default=16,
                    help="KV allocator block granularity (tokens)")
    ap.add_argument("--hbm-budget", type=int, default=0, metavar="BYTES",
                    help="M_max HBM budget override; 0 derives it from "
                         "the hardware profile")
    ap.add_argument("--batch-buckets", type=parse_buckets, default=None,
                    metavar="B1,B2,...",
                    help="compiled decode batch shapes, e.g. '1,2,4,8'; "
                         "default: powers of two up to --b-max")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    # trace replay + per-request goodput SLOs (DESIGN §15)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="replay a repro-trace JSONL file (DESIGN §15) "
                         "instead of synthesizing random prompts: token "
                         "records submit verbatim (ids clamped into the "
                         "model vocab), length-only records get synthetic "
                         "tokens, per-request max-new = min(l_out, "
                         "--max-new); overrides --requests")
    ap.add_argument("--ttft-sla", type=float, default=0.0, metavar="S",
                    help="per-request TTFT goodput SLA in seconds "
                         "(ttft_sla_s); 0 disables the check (DESIGN §15)")
    ap.add_argument("--tbt-sla", type=float, default=0.0, metavar="MS",
                    help="per-request mean-TBT goodput SLA in ms "
                         "(tbt_sla_ms); 0 disables the check (DESIGN §15)")
    ap.add_argument("--pool-tokens", type=int, default=4096)
    ap.add_argument("--max-context", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    # PD fusion (DESIGN §6)
    ap.add_argument("--chunked", action="store_true",
                    help="PD-fusion mode (chunked prefill)")
    ap.add_argument("--lanes", type=int, default=1,
                    help="concurrent prefill lanes")
    ap.add_argument("--pack", default="fifo", choices=["fifo", "srf"],
                    help="lane packer policy")
    ap.add_argument("--chunk-budget", type=int, default=512,
                    help="prefill token budget per fused interval")
    # paged KV cache (DESIGN §9)
    ap.add_argument("--paged", action="store_true",
                    help="physically paged KV cache (block-table pools)")
    # prefix sharing (DESIGN §10)
    ap.add_argument("--prefix-cache", action="store_true",
                    help="ref-counted automatic prefix sharing "
                         "(requires --paged; attention-only families)")
    # two-tier KV memory (DESIGN §11)
    ap.add_argument("--swap-space", type=int, default=0, metavar="BLOCKS",
                    help="host-side swap pool size in KV blocks; 0 keeps "
                         "recompute-only preemption (requires --paged; "
                         "attention-only families)")
    ap.add_argument("--preempt", default="auto",
                    choices=["auto", "swap", "recompute"],
                    help="preemption flavor under pool pressure: 'auto' "
                         "applies the swap-vs-recompute cost crossover, "
                         "'swap' forces swap whenever possible, "
                         "'recompute' disables swapping")
    ap.add_argument("--profile", default="a100x8",
                    choices=sorted(PROFILES),
                    help="hardware profile the 'auto' crossover prices "
                         "PCIe vs re-prefill against (DESIGN §11)")
    # async dispatch-ahead pipeline (DESIGN §14)
    ap.add_argument("--overlap-depth", type=int, default=0,
                    help="device steps left in flight while the host "
                         "schedules the next interval: 0 = synchronous "
                         "loop, 1 = dispatch-ahead overlap (DESIGN §14); "
                         "outputs are bitwise-identical at every depth")
    # mesh-sharded serving (DESIGN §12)
    ap.add_argument("--mesh", type=parse_mesh, default=None,
                    metavar="DATA,MODEL",
                    help="run the engine tensor-parallel on this device "
                         "mesh, e.g. '1,2' or '2x2'; the LAST axis is the "
                         "'model' (TP) axis and --pool-tokens becomes a "
                         "PER-CHIP budget (DESIGN §12). On CPU, forced "
                         "host devices are provisioned automatically.")
    args = ap.parse_args()

    if args.mesh:
        n = 1
        for s in args.mesh:
            n *= s
        ensure_cpu_devices(n)

    import jax

    if args.mesh and len(jax.devices()) < n:
        raise SystemExit(
            f"--mesh {','.join(map(str, args.mesh))} needs {n} devices but "
            f"jax sees {len(jax.devices())}. On CPU this usually means "
            f"XLA_FLAGS already pins --xla_force_host_platform_device_count "
            f"below {n} (ensure_cpu_devices won't override it) — unset it "
            f"or raise it to {n}.")
    import jax.numpy as jnp
    import numpy as np

    from repro.models.model import build_model, default_enc_len
    from repro.serving.cost_model import CostModel
    from repro.serving.engine import Engine

    cfg = get_config(args.arch, args.variant)
    model = build_model(cfg, dtype=jnp.float32 if args.variant == "reduced"
                        else jnp.bfloat16)
    params = model.init(jax.random.PRNGKey(args.seed))
    buckets = args.batch_buckets or \
        tuple(2 ** i for i in range(0, args.b_max.bit_length()))
    serve = ServeConfig(policy=args.policy,
                        b_min=args.b_min, b_max=args.b_max,
                        d_sla_ms=args.sla_ms,
                        ttft_sla_s=args.ttft_sla,
                        tbt_sla_ms=args.tbt_sla,
                        eps_d_ms=args.eps_d, eps_m=args.eps_m,
                        alpha=args.alpha, delta=args.delta,
                        block_size=args.block_size,
                        hbm_budget_bytes=args.hbm_budget,
                        l0_refresh_interval=args.l0_refresh,
                        max_new_tokens=args.max_new,
                        batch_buckets=buckets,
                        kv_pool_tokens=args.pool_tokens,
                        chunked_prefill=args.chunked,
                        chunk_budget_tokens=args.chunk_budget,
                        n_prefill_lanes=args.lanes,
                        prefill_pack=args.pack,
                        paged_kv=args.paged,
                        prefix_cache=args.prefix_cache,
                        swap_space_blocks=args.swap_space,
                        preempt=args.preempt,
                        overlap_depth=args.overlap_depth,
                        mesh_shape=args.mesh or ())
    enc_len = 16 if default_enc_len(cfg) else 0
    eng = Engine(model, params, serve, max_context=args.max_context,
                 buckets=buckets,
                 prefill_chunk=16, enc_len=enc_len,
                 cost=CostModel(cfg, PROFILES[args.profile]))

    rng = np.random.RandomState(args.seed)

    def mk_extras():
        if not enc_len:
            return None
        key = "enc_frames" if cfg.family.value == "encdec" else "images"
        return {key: jnp.asarray(rng.randn(1, enc_len, cfg.d_model),
                                 jnp.float32)}

    if args.trace:
        # trace replay (DESIGN §15): submissions follow the trace's file
        # order; service is as-fast-as-possible (the engine clock is
        # wall time, arrival gating lives in the simulator twin)
        from repro.serving.workload import load_trace_events, trace_prompts
        events = load_trace_events(args.trace)
        for toks, lo in trace_prompts(events, cfg.vocab_size,
                                      seed=args.seed):
            eng.submit(toks, max_new_tokens=max(1, min(lo, args.max_new)),
                       extras=mk_extras())
    else:
        for _ in range(args.requests):
            eng.submit(list(map(int, rng.randint(0, cfg.vocab_size,
                                                 size=rng.randint(4, 24)))),
                       extras=mk_extras())
    eng.run()
    print({k: round(v, 2) for k, v in eng.summary().items()})


if __name__ == "__main__":
    main()
