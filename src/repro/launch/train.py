"""Training CLI: any --arch (reduced on CPU; full configs are exercised via
the dry-run / a real TPU mesh).

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-2.7b --steps 30
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp

from repro.config.base import TrainConfig
from repro.config.registry import get_config, list_archs
from repro.models.model import build_model
from repro.training.train_loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=list_archs())
    ap.add_argument("--variant", default="reduced", choices=["reduced", "full"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch, args.variant)
    model = build_model(cfg, dtype=jnp.float32 if args.variant == "reduced"
                        else jnp.bfloat16)
    t = TrainConfig(global_batch=args.batch, seq_len=args.seq,
                    steps=args.steps, lr=args.lr,
                    warmup_steps=max(args.steps // 10, 1), log_every=10)
    res = train(model, t, checkpoint_path=args.ckpt or None)
    print(f"final loss {res['losses'][-1]:.4f} "
          f"({res['tokens_per_s']:.0f} tok/s)")


if __name__ == "__main__":
    main()
