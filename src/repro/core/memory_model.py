"""Memory model for the scheduler: the paper's CLT chance-constraint math
(DESIGN §2).

Maps GPU/TPU HBM budget -> token capacity eta, and implements

    mu_S    = b (E[l_in] + E[l_out])                       (8)
    sigma_S = sqrt(b (Var(l_in) + Var(l_out)))             (9)
    P(S > eta) ~ 1 - Phi((eta - mu_S) / sigma_S) <= eps_M  (10)/(11)
    b_max^mem closed form                                   (12)
    L0 = eta - (theta * sigma_S + mu_S);  b <= (eta - L0)/E[l]  (13)/(14)

Per-architecture adaptation (DESIGN §4): the token cost and the *effective*
length moments depend on the family — sliding windows truncate lengths,
SSM state is constant per request (the constraint degenerates to a request
cap), enc-dec/VLM add a fixed per-request cross-KV term.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.config.base import ArchFamily, AttentionKind, ModelConfig
from repro.models import backbone as bb


def norm_ppf(q: float) -> float:
    """Inverse standard-normal CDF (Acklam's rational approximation).

    |relative error| < 1.15e-9 over (0, 1); no scipy dependency.
    """
    if not 0.0 < q < 1.0:
        raise ValueError(f"q must be in (0,1), got {q}")
    a = [-3.969683028665376e+01, 2.209460984245205e+02, -2.759285104469687e+02,
         1.383577518672690e+02, -3.066479806614716e+01, 2.506628277459239e+00]
    b = [-5.447609879822406e+01, 1.615858368580409e+02, -1.556989798598866e+02,
         6.680131188771972e+01, -1.328068155288572e+01]
    c = [-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e+00,
         -2.549732539343734e+00, 4.374664141464968e+00, 2.938163982698783e+00]
    d = [7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e+00,
         3.754408661907416e+00]
    plow, phigh = 0.02425, 1 - 0.02425
    if q < plow:
        u = math.sqrt(-2 * math.log(q))
        return (((((c[0] * u + c[1]) * u + c[2]) * u + c[3]) * u + c[4]) * u + c[5]) / \
            ((((d[0] * u + d[1]) * u + d[2]) * u + d[3]) * u + 1)
    if q > phigh:
        return -norm_ppf(1 - q)
    u = q - 0.5
    t = u * u
    return (((((a[0] * t + a[1]) * t + a[2]) * t + a[3]) * t + a[4]) * t + a[5]) * u / \
        (((((b[0] * t + b[1]) * t + b[2]) * t + b[3]) * t + b[4]) * t + 1)


def norm_cdf(x: float) -> float:
    return 0.5 * (1.0 + math.erf(x / math.sqrt(2.0)))


def kv_shard_factor(cfg: ModelConfig, model_axis: int) -> int:
    """Effective model-parallel shard count of the serving KV pool
    (DESIGN §12).

    The pool shards over the "model" axis on kv-heads, falling back to
    head_dim when kv-heads don't divide (the DESIGN §5 cache rule).
    Returns 1 — pool unsharded, capacity does not scale — when the axis
    is trivial, the family is attention-free (no token pool to shard), or
    neither kv-heads nor head_dim divides the axis. Pure Python so the
    simulator twin can apply the identical rule without touching jax."""
    if model_axis <= 1:
        return 1
    if cfg.kv_bytes_per_token() == 0:
        return 1
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    if kv % model_axis == 0 or hd % model_axis == 0:
        return model_axis
    return 1


@dataclasses.dataclass
class MemoryModel:
    """Token-capacity accounting for one architecture on one device budget.

    Chip-aware under mesh-sharded serving (DESIGN §12): `hbm_budget_bytes`
    and `eta_tokens` are PER-CHIP quantities, and `model_shards` (the
    effective model-axis shard count, see `kv_shard_factor`) scales the
    pool — each chip holds 1/m of every token's KV bytes, so the same
    per-chip HBM backs m× the tokens. `model_shards = 1` (default) keeps
    the legacy single-device accounting byte-for-byte."""

    cfg: ModelConfig
    hbm_budget_bytes: int            # M_max per chip: free HBM after params+activations
    eps_m: float = 0.05
    kv_dtype_bytes: int = 2
    block_size: int = 16             # allocator granularity (vLLM-style blocks)
    eta_tokens: int = 0              # explicit per-chip token-pool override (engine)
    model_shards: int = 1            # model-axis shards of the KV pool (DESIGN §12)

    def __post_init__(self):
        self.theta = norm_ppf(1.0 - self.eps_m)
        self._bpt = self.cfg.kv_bytes_per_token(self.kv_dtype_bytes)

    # -- capacity ---------------------------------------------------------
    @property
    def bytes_per_token(self) -> int:
        return self._bpt

    def fixed_bytes_per_request(self, enc_len: int = 0) -> int:
        """Per-request state independent of generated length (SSM state,
        conv state, cross-KV, window-capped KV)."""
        cfg = self.cfg
        if cfg.family == ArchFamily.SSM:
            return bb.cache_bytes(cfg, 1, 1)
        extra = 0
        if cfg.family in (ArchFamily.ENCDEC, ArchFamily.VLM) and enc_len:
            hd = cfg.resolved_head_dim
            n_cross = (cfg.num_layers if cfg.family == ArchFamily.ENCDEC
                       else cfg.num_cross_layers)
            extra = 2 * n_cross * enc_len * cfg.num_kv_heads * hd * self.kv_dtype_bytes
        if cfg.family == ArchFamily.HYBRID:
            # recurrent + conv state
            w = cfg.rglru.lru_width or cfg.d_model
            kinds = cfg.layer_kinds()
            n_rec = sum(1 for k in kinds if k == "recurrent")
            extra += n_rec * (w * 4 + (cfg.rglru.conv_width - 1) * w * self.kv_dtype_bytes)
        return extra

    @property
    def eta(self) -> int:
        """Max concurrent tokens in the KV pool (eq. context, block-rounded).

        Scales with `model_shards`: per-chip budget × shards worth of
        tokens fit when each token's KV is split over the model axis
        (DESIGN §12)."""
        if self.eta_tokens:
            tokens = self.eta_tokens * self.model_shards
            return (tokens // self.block_size) * self.block_size
        if self._bpt == 0:
            return 0
        tokens = self.hbm_budget_bytes * self.model_shards // self._bpt
        return (tokens // self.block_size) * self.block_size

    @property
    def num_blocks(self) -> int:
        """Physical pool blocks for the paged KV cache: the allocator's
        block count IS the pool's leading dimension (DESIGN §9)."""
        return self.eta // self.block_size

    def tokens_to_bytes(self, tokens: int) -> int:
        """Usage-reporting helper (DESIGN §10): the BlockManager's logical
        (per-request) vs physical (deduped) token counts expressed in HBM
        bytes, so operators see what prefix sharing actually saves."""
        return tokens * self._bpt

    def blocks_to_bytes(self, n_blocks: int) -> int:
        """KV payload bytes held by n_blocks allocator blocks — the unit
        the swap counters charge per transferred block in BOTH engine and
        sim, so the twins' byte telemetry stays comparable (DESIGN §11)."""
        return n_blocks * self.block_size * self._bpt

    def max_requests_state_only(self) -> int:
        """SSM-style cap: requests whose state fits the budget."""
        per = self.fixed_bytes_per_request()
        return max(1, self.hbm_budget_bytes // max(per, 1))

    # -- effective length moments (family-aware truncation) ----------------
    def effective_moments(self, mean_in: float, var_in: float,
                          mean_out: float, var_out: float):
        """Per-request token-footprint moments. Window-attention families
        cap the footprint at the window size (ring buffer)."""
        cfg = self.cfg
        w = 0
        if cfg.attention == AttentionKind.SLIDING:
            w = cfg.sliding_window
        elif cfg.attention == AttentionKind.LOCAL_HYBRID:
            w = cfg.rglru.window_size
        mu = mean_in + mean_out
        var = var_in + var_out
        if w and mu > w:
            # footprint = min(l, w): approximate truncation — mean capped at
            # w, variance shrinks toward 0 as mass concentrates at the cap
            frac = w / mu
            mu = w
            var = var * frac * frac
        return mu, max(var, 0.0)

    # -- the paper's equations ---------------------------------------------
    def mu_sigma(self, b: int, mu_l: float, var_l: float):
        mu_s = b * mu_l                           # (8)
        sigma_s = math.sqrt(max(b * var_l, 0.0))  # (9)
        return mu_s, sigma_s

    def overflow_prob(self, b: int, mu_l: float, var_l: float) -> float:
        """P(S > eta) via the CLT normal approximation (10)."""
        if self._bpt == 0:
            return 0.0 if b <= self.max_requests_state_only() else 1.0
        mu_s, sigma_s = self.mu_sigma(b, mu_l, var_l)
        if sigma_s == 0.0:
            return 0.0 if mu_s <= self.eta else 1.0
        return 1.0 - norm_cdf((self.eta - mu_s) / sigma_s)

    def b_mem_closed_form(self, mu_l: float, var_l: float) -> int:
        """Eq. (12): largest b with P(S > eta) <= eps_M (future-work exact
        form; kept for tests & ablation)."""
        if self._bpt == 0:
            return self.max_requests_state_only()
        if mu_l <= 0:
            return 1
        sig1 = math.sqrt(max(var_l, 0.0))           # sigma_S at b=1
        th = self.theta * sig1
        disc = th * th + 4 * mu_l * self.eta
        root = (math.sqrt(disc) - th) / (2 * mu_l)  # sqrt(b) from the quadratic
        return max(int(root * root), 1)

    def safety_buffer_L0(self, b: int, mu_l: float, var_l: float) -> float:
        """L0 = eta - (theta*sigma_S + mu_S), evaluated at batch size b."""
        mu_s, sigma_s = self.mu_sigma(b, mu_l, var_l)
        return self.eta - (self.theta * sigma_s + mu_s)

    def b_mem_linear(self, L0: float, mu_l: float) -> int:
        """Eq. (14): b <= (eta - L0) / E[l] — the online linear rule."""
        if self._bpt == 0:
            return self.max_requests_state_only()
        if mu_l <= 0:
            return 1
        return max(int((self.eta - L0) // mu_l), 1)
