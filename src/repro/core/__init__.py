# The paper's primary contribution: memory-aware + SLA-constrained dynamic
# batching as a real-time control loop over the serving engine.
from repro.core.batching import (BatchingMemory, BatchingSLA,  # noqa: F401
                                 CombinedPolicy, StaticPolicy, make_policy)
from repro.core.memory_model import MemoryModel  # noqa: F401
from repro.core.telemetry import Telemetry  # noqa: F401
