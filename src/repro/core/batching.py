"""The paper's two dynamic batching algorithms + the combined policy.

Algorithm 1 (BatchingMemory)  — memory-constrained dynamic batching, eq. (14)
Algorithm 2 (BatchingSLA)     — SLA-constrained noisy binary search on b_t
Combined                      — b* = min(b_mem, b_SLA)            (paper §III-B)
Static                        — vLLM-style fixed max batch (the baseline)

Every policy is a pure-Python controller called once per scheduling interval
with a TelemetrySnapshot; it returns a BatchDecision (the middle layer of the
controller stack, DESIGN §1). The engine/simulator enforces the decision:
admission control against the block pool, plus — in PD-fusion mode — the
chunked-prefill token budget packed across prefill lanes (DESIGN §6).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.config.base import ServeConfig
from repro.core.memory_model import MemoryModel
from repro.core.telemetry import TelemetrySnapshot


@dataclasses.dataclass
class BatchDecision:
    """One scheduling interval's output: b_t plus the PD-fusion token budget
    the packer may spend on prefill chunks (DESIGN §1, §6)."""
    max_batch: int                   # b_t: concurrent-request cap this interval
    chunk_budget: int = 0            # PD-fusion token budget (0 = no fusion)
    b_mem: int = 0                   # diagnostics
    b_sla: int = 0


class Policy:
    """Controller interface (DESIGN §1): TelemetrySnapshot -> BatchDecision,
    once per scheduling interval. Stateful subclasses implement the paper's
    Algorithms 1 & 2."""

    name = "base"

    def step(self, tel: TelemetrySnapshot) -> BatchDecision:
        raise NotImplementedError


class StaticPolicy(Policy):
    """vLLM baseline: a fixed preset max batch size (max_num_seqs) — the
    paper's static-batching comparison row (Table I; DESIGN §1)."""

    name = "static"

    def __init__(self, cfg: ServeConfig):
        self.cfg = cfg

    def step(self, tel: TelemetrySnapshot) -> BatchDecision:
        return BatchDecision(max_batch=self.cfg.b_max,
                             chunk_budget=self.cfg.chunk_budget_tokens
                             if self.cfg.chunked_prefill else 0)


class BatchingMemory(Policy):
    """Paper Algorithm 1 — memory-constrained dynamic batching (DESIGN §2).

    L0 <- eta - (theta * sigma_S + mu_S)          (line 1; refreshed periodically)
    b_t <- b_{t-1}
    if N^d > 0 and N^p > 0:
        b_t <- floor((eta - L0) / (E[l_in] + E[l_out]))   (eq. 14)
    b_t <- min(max(b_t, N^d), B_max)

    The L0 refresh uses the rigorous closed form (12) — see `_refresh_L0`
    and DESIGN §2.3 for why the paper's printed residual is replaced.
    """

    name = "memory"

    def __init__(self, cfg: ServeConfig, mem: MemoryModel):
        self.cfg = cfg
        self.mem = mem
        self.b_prev = cfg.b_max
        self.L0: Optional[float] = None
        self._ticks = 0

    def _refresh_L0(self, tel: TelemetrySnapshot):
        """L0 refresh (Alg 1 line 1).

        The paper's printed L0 = eta - (theta*sigma_S + mu_S) is a feedback
        residual that goes negative (and over-admits) when the reference
        batch exceeds capacity; the paper lists replacing it with the
        rigorous form (12) as future work (§IV). We implement that form:
        L0 = theta * sigma_S(b*) with b* from the closed-form (12), which
        makes the online linear rule (14) exact: (eta - L0)/E[l] = b*.
        """
        mu_l, var_l = self.mem.effective_moments(
            tel.mean_in, tel.var_in, tel.mean_out, tel.var_out)
        if mu_l <= 0:
            return
        b_star = self.mem.b_mem_closed_form(mu_l, var_l)
        self.L0 = max(self.mem.theta * math.sqrt(max(b_star * var_l, 0.0)),
                      0.0)

    def step(self, tel: TelemetrySnapshot) -> BatchDecision:
        if self.L0 is None or self._ticks % self.cfg.l0_refresh_interval == 0:
            self._refresh_L0(tel)
        self._ticks += 1

        b_t = self.b_prev
        mu_l, _ = self.mem.effective_moments(
            tel.mean_in, tel.var_in, tel.mean_out, tel.var_out)
        if tel.n_decode_running > 0 and tel.n_prefill_waiting > 0 \
                and self.L0 is not None and mu_l > 0:
            # swap pressure (DESIGN §11): the swapped-out backlog holds a
            # claim on eta — treat its tokens as part of the safety buffer
            # so (eta - L0 - swapped)/E[l] caps admission accordingly
            b_t = self.mem.b_mem_linear(self.L0 + tel.swapped_tokens, mu_l)
        b_t = min(max(b_t, tel.n_decode_running), self.cfg.b_max)
        b_t = max(b_t, self.cfg.b_min)
        self.b_prev = b_t
        return BatchDecision(max_batch=b_t, b_mem=b_t,
                             chunk_budget=self._chunk_budget(b_t, tel))

    def _chunk_budget(self, b_t: int, tel: TelemetrySnapshot) -> int:
        if not self.cfg.chunked_prefill:
            return 0
        # PD fusion: the controller's b_t is a per-step token budget; decode
        # requests consume 1 token each, the remainder goes to prefill chunks
        return max(b_t - tel.n_decode_running, 0)


class BatchingSLA(Policy):
    """Paper Algorithm 2 — SLA-constrained noisy binary search (DESIGN §1.2).

    Maintains [b_low, b_high]; compares recent mean TBT tau-bar against
    D_SLA +/- eps_D and narrows/recenters the window; emits the midpoint.
    alpha controls the window width, delta relaxes against noise.
    """

    name = "sla"

    def __init__(self, cfg: ServeConfig):
        assert cfg.d_sla_ms > 0, "BatchingSLA requires d_sla_ms"
        self.cfg = cfg
        self.b_low = cfg.b_min
        self.b_high = cfg.b_max

    def step(self, tel: TelemetrySnapshot) -> BatchDecision:
        c = self.cfg
        tau = tel.tbt_ms
        b_bar = int(round(tel.mean_batch)) or self.b_low
        if tel.tbt_samples <= 0:
            # cold start: an empty TBT window reads as tau = 0.0, which the
            # headroom branch would take as "under SLA" every interval,
            # ratcheting the window to b_max before a single decode step has
            # been measured. Hold the window and emit the midpoint until
            # at least one on_decode_step sample exists.
            b_t = (self.b_low + self.b_high) // 2
            b_t = min(max(b_t, tel.n_decode_running), c.b_max)
            b_t = max(b_t, c.b_min)
            return BatchDecision(max_batch=b_t, b_sla=b_t,
                                 chunk_budget=self._chunk_budget(b_t, tel))
        if tau > c.d_sla_ms + c.eps_d_ms:
            # too slow: clamp the ceiling down to the observed batch
            self.b_high = max(b_bar, self.b_low + c.alpha)
            self.b_low = max(self.b_low - c.delta, c.b_min)
        elif tau < c.d_sla_ms - c.eps_d_ms:
            # headroom: raise the floor toward the observed batch
            self.b_low = min(b_bar, self.b_high - c.alpha)
            self.b_high = min(self.b_high + c.delta, c.b_max)
        else:
            # in band: tighten the window around the observed batch
            self.b_high = min(b_bar + c.alpha // 2, c.b_max)
            self.b_low = max(b_bar - c.alpha // 2, c.b_min)
        self.b_low = max(min(self.b_low, self.b_high), c.b_min)
        self.b_high = min(max(self.b_high, self.b_low), c.b_max)
        b_t = (self.b_low + self.b_high) // 2
        b_t = min(max(b_t, tel.n_decode_running), c.b_max)
        b_t = max(b_t, c.b_min)
        return BatchDecision(max_batch=b_t, b_sla=b_t,
                             chunk_budget=self._chunk_budget(b_t, tel))

    def _chunk_budget(self, b_t: int, tel: TelemetrySnapshot) -> int:
        if not self.cfg.chunked_prefill:
            return 0
        return max(b_t - tel.n_decode_running, 0)


class CombinedPolicy(Policy):
    """b* = min(b_mem, b_SLA) — the paper's full method (§III-B; DESIGN
    §1.2). In PD-fusion mode the fused chunk budget is likewise the min of
    the two policies' budgets."""

    name = "combined"

    def __init__(self, cfg: ServeConfig, mem: MemoryModel):
        self.memory = BatchingMemory(cfg, mem)
        self.sla = BatchingSLA(cfg) if cfg.d_sla_ms > 0 else None
        self.cfg = cfg

    def step(self, tel: TelemetrySnapshot) -> BatchDecision:
        dm = self.memory.step(tel)
        if self.sla is None:
            return dm
        ds = self.sla.step(tel)
        b = min(dm.max_batch, ds.max_batch)
        b = min(max(b, tel.n_decode_running, self.cfg.b_min), self.cfg.b_max)
        chunk = min(dm.chunk_budget, ds.chunk_budget) \
            if self.cfg.chunked_prefill else 0
        return BatchDecision(max_batch=b, chunk_budget=chunk,
                             b_mem=dm.max_batch, b_sla=ds.max_batch)


def bucketize(b: int, buckets) -> int:
    """Round b DOWN to the nearest compiled bucket (TPU static shapes,
    DESIGN §3); never below the smallest bucket."""
    if not buckets:
        return b
    le = [x for x in buckets if x <= b]
    return max(le) if le else min(buckets)


def make_policy(cfg: ServeConfig, mem: MemoryModel) -> Policy:
    if cfg.policy == "static":
        return StaticPolicy(cfg)
    if cfg.policy == "memory":
        return BatchingMemory(cfg, mem)
    if cfg.policy == "sla":
        return BatchingSLA(cfg)
    if cfg.policy == "combined":
        return CombinedPolicy(cfg, mem)
    raise ValueError(f"unknown policy {cfg.policy!r}")
