"""Rolling telemetry the controller consumes each scheduling interval.

Tracks request arrival rate lambda(t), prompt/output length moments
(EW-windowed), recent decode latency tau-bar (TBT), recent decode batch
size b-bar, and — in PD-fusion mode — per-lane prefill occupancy and
TTFT attribution (queueing vs prefill service, DESIGN §6). Pure Python —
shared by the real engine and the simulator (DESIGN §1).
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Deque, Dict, Mapping, Optional


@dataclasses.dataclass
class TelemetrySnapshot:
    n_prefill_waiting: int = 0       # N^p: requests with prefill work pending
    n_decode_running: int = 0        # N^d: requests currently decoding
    mean_in: float = 0.0             # E[l_in]
    var_in: float = 0.0
    mean_out: float = 0.0            # E[l_out] (observed completions, EW)
    var_out: float = 0.0
    tbt_ms: float = 0.0              # tau-bar: recent mean decode latency
    tbt_samples: int = 0             # decode steps in the TBT window (0 = cold)
    mean_batch: float = 0.0          # b-bar: recent mean decode batch size
    arrival_rate: float = 0.0        # lambda(t) req/s
    free_tokens: int = 0             # free KV-pool tokens (blocks*block_size)
    # prefix sharing (DESIGN §10): per-request footprints summed vs deduped
    # distinct-block usage — free_tokens counts evictable cached blocks as
    # free, these two make the dedup visible to the controller/operator
    logical_used_tokens: int = 0
    physical_used_tokens: int = 0
    # two-tier swap pressure (DESIGN §11): device tokens the swapped-out
    # backlog will re-claim on swap-in. Alg 1 subtracts this from its
    # capacity so admission cannot hand the swapped queue's headroom to
    # new requests and starve the swap-in path.
    swapped_tokens: int = 0
    now: float = 0.0
    # PD fusion (DESIGN §6): recent mean fraction of prefill lanes packed
    # with work, and EW-mean TTFT split into queueing vs prefill service
    prefill_lane_occupancy: float = 0.0
    ttft_queue_s: float = 0.0
    ttft_prefill_s: float = 0.0
    # async dispatch-ahead split (DESIGN §14): recent mean wall-time per
    # scheduling interval spent on host work (admission, lane packing,
    # block-table edits) vs blocked at the device-step retirement fence.
    # Under overlap the device share is the *marginal* wait — device time
    # the host could not hide — so host+device still sum to the interval.
    step_host_s: float = 0.0
    step_device_s: float = 0.0


class _Welford:
    """Exponentially-weighted mean/variance."""

    def __init__(self, halflife: float = 256.0):
        self.alpha = 1.0 - math.exp(-math.log(2.0) / halflife)
        self.mean: Optional[float] = None
        self.var = 0.0

    def update(self, x: float):
        if self.mean is None:
            self.mean = x
            self.var = 0.0
            return
        d = x - self.mean
        self.mean += self.alpha * d
        self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)

    def get(self, default_mean: float = 0.0, default_var: float = 0.0):
        if self.mean is None:
            return default_mean, default_var
        return self.mean, self.var


class Telemetry:
    def __init__(self, window: int = 32, halflife: float = 256.0,
                 prior_mean_in: float = 0.0, prior_mean_out: float = 0.0):
        self.len_in = _Welford(halflife)
        self.len_out = _Welford(halflife)
        self.tbt: Deque[float] = collections.deque(maxlen=window)
        self.batch: Deque[int] = collections.deque(maxlen=window)
        self.arrivals: Deque[float] = collections.deque(maxlen=4 * window)
        self.prior_mean_in = prior_mean_in
        self.prior_mean_out = prior_mean_out
        # PD-fusion lane stats (DESIGN §6)
        self.lane_occ: Deque[float] = collections.deque(maxlen=window)
        self.lane_tokens: Dict[int, int] = {}     # lane -> prefill tokens packed
        self.lane_chunks: Dict[int, int] = {}     # lane -> chunks packed
        self.prefill_tokens_total = 0
        self.ttft_queue = _Welford(halflife)
        self.ttft_prefill = _Welford(halflife)
        # host-vs-device interval split (DESIGN §14)
        self.host_s: Deque[float] = collections.deque(maxlen=window)
        self.device_s: Deque[float] = collections.deque(maxlen=window)

    # -- event feeds --------------------------------------------------------
    def on_arrival(self, t: float, prompt_len: int):
        self.arrivals.append(t)
        self.len_in.update(float(prompt_len))

    def on_completion(self, output_len: int):
        self.len_out.update(float(output_len))

    def on_decode_step(self, tbt_ms: float, batch_size: int):
        self.tbt.append(tbt_ms)
        self.batch.append(batch_size)

    def on_prefill_interval(self, lane_tokens: Mapping[int, int],
                            n_lanes: int):
        """One PD-fused interval packed `lane_tokens[lane]` prefill tokens
        into each listed lane (DESIGN §6); n_lanes is the configured total."""
        self.lane_occ.append(len(lane_tokens) / max(n_lanes, 1))
        for lane, toks in lane_tokens.items():
            self.lane_tokens[lane] = self.lane_tokens.get(lane, 0) + toks
            self.lane_chunks[lane] = self.lane_chunks.get(lane, 0) + 1
            self.prefill_tokens_total += toks

    def on_first_token(self, queue_s: float, prefill_s: float):
        """TTFT attribution: time queued before the first prefill chunk vs
        time being chunk-prefilled until the first token (DESIGN §6)."""
        self.ttft_queue.update(max(queue_s, 0.0))
        self.ttft_prefill.update(max(prefill_s, 0.0))

    def on_interval(self, host_s: float, device_s: float):
        """One scheduling interval's wall-time split: host work (admission,
        lane packing, table edits) vs blocked wait at the retirement fence
        (DESIGN §14). Fed immediately, not via the stale-by-one contract —
        it describes the host loop itself, not the device step's output."""
        self.host_s.append(host_s)
        self.device_s.append(device_s)

    # -- snapshot ------------------------------------------------------------
    def arrival_rate(self, now: float, horizon: float = 10.0) -> float:
        """Arrivals per second over the observation horizon.

        Divides by the full horizon (clamped to elapsed time), NOT by
        `now - recent[0]`: a single fresh arrival would otherwise yield a
        1/1e-6 = 1e6 req/s spike that poisons the controller's lambda(t)."""
        recent = [a for a in self.arrivals if a > now - horizon]
        if not recent:
            return 0.0
        span = max(min(now, horizon), 1e-6)
        return len(recent) / span

    def snapshot(self, *, now: float, n_prefill: int, n_decode: int,
                 free_tokens: int, logical_used_tokens: int = 0,
                 physical_used_tokens: int = 0,
                 swapped_tokens: int = 0) -> TelemetrySnapshot:
        mi, vi = self.len_in.get(self.prior_mean_in, 0.0)
        mo, vo = self.len_out.get(self.prior_mean_out, 0.0)
        tbt = sum(self.tbt) / len(self.tbt) if self.tbt else 0.0
        mb = sum(self.batch) / len(self.batch) if self.batch else 0.0
        occ = sum(self.lane_occ) / len(self.lane_occ) if self.lane_occ else 0.0
        tq, _ = self.ttft_queue.get()
        tp, _ = self.ttft_prefill.get()
        hs = sum(self.host_s) / len(self.host_s) if self.host_s else 0.0
        ds = sum(self.device_s) / len(self.device_s) if self.device_s else 0.0
        return TelemetrySnapshot(
            n_prefill_waiting=n_prefill, n_decode_running=n_decode,
            mean_in=mi, var_in=vi, mean_out=mo, var_out=vo,
            tbt_ms=tbt, tbt_samples=len(self.tbt), mean_batch=mb,
            arrival_rate=self.arrival_rate(now), free_tokens=free_tokens,
            logical_used_tokens=logical_used_tokens,
            physical_used_tokens=physical_used_tokens,
            swapped_tokens=swapped_tokens,
            now=now, prefill_lane_occupancy=occ,
            ttft_queue_s=tq, ttft_prefill_s=tp,
            step_host_s=hs, step_device_s=ds)
