"""Shared PD-fusion lane packer (DESIGN §6).

One implementation of the lane ordering + token-budget chunk packing used
by BOTH the real engine (`serving.engine.Engine`) and its discrete-event
twin (`serving.sim.ServingSimulator`), so the scheduling semantics cannot
drift between them. Pure functions over (lane, request) state — no cache
or clock dependencies.
"""
from __future__ import annotations

from typing import Iterable, List, Optional, Sequence, Tuple


def lane_order(pack: str, pairs: Iterable[Tuple]) -> List[Tuple]:
    """Packer policy ordering over (lane, request) pairs.

    'fifo' keeps the given (arrival/queue) order; 'srf' orders by shortest
    remaining prefill (rid tiebreak keeps it deterministic).
    """
    pairs = list(pairs)
    if pack == "srf":
        return sorted(pairs, key=lambda jr: (
            jr[1].prompt_len - jr[1].prefill_pos, jr[1].rid))
    return pairs


def _budget_order(pack: str, occupied: List[Tuple]) -> List[Tuple]:
    """Ordering for budget allocation across OCCUPIED lanes.

    fifo must mean arrival order, not lane-index order: with a tight
    budget, index order would let lane 0 — refilled with ever-newer
    arrivals — starve an older request parked in a higher lane forever.
    """
    if pack == "srf":
        return lane_order(pack, occupied)
    return sorted(occupied, key=lambda jr: (jr[1].arrival_time, jr[1].rid))


def pack_chunks(pack: str, lanes: Sequence[Optional[object]],
                budget_tokens: int,
                chunk_cap: int = 0) -> List[Tuple[int, object, int]]:
    """Split one interval's token budget across occupied lanes.

    One chunk per lane per interval, each exactly
    min(budget left, chunk_cap, remaining) tokens — exact-size tail chunks
    so stateful families never see pad tokens. chunk_cap = 0 means
    uncapped (a lane may take its whole remaining prompt; simulator-only).
    Returns [(lane, request, take)] in packing order.
    """
    plan: List[Tuple[int, object, int]] = []
    left = budget_tokens
    for j, r in _budget_order(pack, [(j, r) for j, r in enumerate(lanes)
                                     if r is not None]):
        if left <= 0:
            break
        cap = chunk_cap or (r.prompt_len - r.prefill_pos)
        take = min(left, cap, r.prompt_len - r.prefill_pos)
        if take <= 0:
            continue
        plan.append((j, r, take))
        left -= take
    return plan
