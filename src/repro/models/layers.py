"""Shared transformer layers: norms, RoPE, attention (with unified ring/full
KV cache), SwiGLU MLP, and GShard-style dense-dispatch MoE.

Conventions
-----------
* params are nested dicts of jnp arrays; layer stacks carry a leading L axis.
* activations default to the config dtype; softmax/norm accumulate in fp32.
* attention caches store absolute positions per physical slot (`pos`, int32,
  -1 = empty). This unifies full caches and ring-buffer (sliding-window)
  caches: masking is purely position arithmetic, and RoPE is applied at
  absolute positions before the write so ring wrap-around is transparent.
"""
from __future__ import annotations

import math
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig


def use_pallas() -> bool:
    """Pallas kernels are the default backend on TPU; REPRO_USE_PALLAS=1
    forces them on CPU (interpret mode — used by the integration tests)."""
    env = os.environ.get("REPRO_USE_PALLAS")
    if env is not None:
        return env == "1"
    return jax.default_backend() == "tpu"

# ---------------------------------------------------------------------------
# init helpers


def dense_init(key, shape, scale: Optional[float] = None, dtype=jnp.float32):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = (1.0 / math.sqrt(fan_in)) if scale is None else scale
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def rms_norm(x, w, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE


def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float):
    """x: (..., T, H, hd); positions: broadcastable to (..., T)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., T, hd/2)
    cos = jnp.cos(ang)[..., None, :]                    # (..., T, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention core

_NEG_INF = -1e30


def attend(q, k, v, q_pos, k_pos, *, window: int = 0, causal: bool = True,
           k_valid=None):
    """Masked GQA attention.

    q: (B, Tq, H, hd); k/v: (B, Tk, KV, hd)
    q_pos: (B, Tq) int32 absolute positions of queries
    k_pos: (B, Tk) int32 absolute positions of keys (-1 = empty slot)
    window: if >0, keys older than q_pos - window + 1 are masked
    k_valid: optional (B, Tk) bool extra mask (e.g. encoder padding)
    """
    B, Tq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    # fp32 ACCUMULATION without materializing fp32 copies of the KV cache
    # (an .astype(f32) on k/v doubles the decode memory term — §Perf iter C)
    qh = q.reshape(B, Tq, KV, G, hd)
    scores = jnp.einsum("btkgd,bskd->bkgts", qh, k,
                        preferred_element_type=jnp.float32) / math.sqrt(hd)
    mask = k_pos[:, None, :] >= 0                        # (B, Tq->1?, Tk)
    mask = jnp.broadcast_to(mask, (B, Tq, k.shape[1]))
    if causal:
        mask = mask & (k_pos[:, None, :] <= q_pos[:, :, None])
    if window:
        mask = mask & (k_pos[:, None, :] > q_pos[:, :, None] - window)
    if k_valid is not None:
        mask = mask & k_valid[:, None, :]
    scores = jnp.where(mask[:, None, None, :, :], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)          # fp32
    out = jnp.einsum("bkgts,bskd->btkgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Tq, H * hd).astype(q.dtype)


def init_attention(key, cfg: ModelConfig, dtype, *, cross: bool = False):
    d, H, KV = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H * hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, KV * hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, KV * hd), dtype=dtype),
        "wo": dense_init(ks[3], (H * hd, d),
                         scale=0.02 / math.sqrt(2 * max(cfg.num_layers, 1)),
                         dtype=dtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    if cross:
        p["gate"] = jnp.zeros((), dtype)   # tanh-gated cross-attn (VLM)
    return p


def attention_qkv(p, x, cfg: ModelConfig):
    B, T, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (q.reshape(B, T, H, hd), k.reshape(B, T, KV, hd),
            v.reshape(B, T, KV, hd))


def self_attention_train(p, x, positions, cfg: ModelConfig, *, window: int = 0):
    """Full-sequence causal self-attention (no cache)."""
    q, k, v = attention_qkv(p, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = attend(q, k, v, positions, positions, window=window, causal=True)
    return out @ p["wo"]


def self_attention_cached(p, x, positions, cache_k, cache_v, cache_pos,
                          cfg: ModelConfig, *, window: int = 0):
    """Self-attention through a (possibly ring) KV cache.

    x: (B, T, d) new tokens at absolute `positions` (B, T).
    cache_k/v: (B, S_phys, KV, hd); cache_pos: (B, S_phys) absolute pos, -1 empty.
    Returns (out, new_cache_k, new_cache_v, new_cache_pos).
    """
    B, T, _ = x.shape
    S = cache_k.shape[1]
    q, k, v = attention_qkv(p, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    # ring slot (== pos when S >= ctx); padding (pos < 0) writes out of
    # bounds and is dropped by the scatter
    slots = jnp.where(positions >= 0, positions % S, S)
    bidx = jnp.arange(B)[:, None]
    cache_k = cache_k.at[bidx, slots].set(k, mode="drop")
    cache_v = cache_v.at[bidx, slots].set(v, mode="drop")
    cache_pos = cache_pos.at[bidx, slots].set(positions, mode="drop")
    if T == 1 and use_pallas():
        # flash-decode Pallas kernel (kernels/decode_attention.py)
        from repro.kernels import ops
        out = ops.decode_attention(q[:, 0], cache_k, cache_v,
                                   positions[:, 0], cache_pos, window=window)
        out = out.reshape(B, 1, -1)
    else:
        out = attend(q, cache_k, cache_v, positions, cache_pos,
                     window=window, causal=True)
    return out @ p["wo"], cache_k, cache_v, cache_pos


def _pool_write(pool, flat_slots, val):
    """Scatter per-token values into a flattened paged pool (DESIGN §9).

    pool: (NB, bs, ...); flat_slots: (B, T) flat indices into NB*bs, with
    out-of-bounds (NB*bs) marking padding/unallocated tokens (dropped)."""
    NB, bs = pool.shape[:2]
    flat = pool.reshape((NB * bs,) + pool.shape[2:])
    return flat.at[flat_slots].set(val, mode="drop").reshape(pool.shape)


def paged_view(pool_k, pool_v, pool_pos, tables):
    """Gather a per-request contiguous (B, MB*bs) view of the paged pools
    (DESIGN §9). Delegates to the canonical block-table gather in
    `kernels.ref` so the production path and the kernel oracle can never
    diverge on layout semantics."""
    from repro.kernels.ref import paged_view as _paged_view
    return _paged_view(pool_k, pool_v, pool_pos, tables)


def self_attention_paged(p, x, positions, pool_k, pool_v, pool_pos, tables,
                         cfg: ModelConfig, *, window: int = 0):
    """Self-attention through the physically paged KV pool (DESIGN §9).

    x: (B, T, d) new tokens at absolute `positions` (B, T); pool_k/v:
    (NB, bs, KV, hd) shared physical pools; pool_pos: (NB, bs) absolute
    positions (-1 = empty); tables: (B, MB) per-request physical block ids
    (-1 = unallocated). A token at position p is written to block
    tables[b, p // bs], offset p % bs; padding (p < 0) and unallocated
    blocks drop. Returns (out, new_pool_k, new_pool_v, new_pool_pos).
    """
    B, T, _ = x.shape
    NB, bs = pool_k.shape[:2]
    MB = tables.shape[1]
    q, k, v = attention_qkv(p, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    blk = jnp.clip(positions // bs, 0, MB - 1)
    phys = jnp.take_along_axis(tables, blk, axis=1)            # (B, T)
    ok = (positions >= 0) & (phys >= 0)
    flat = jnp.where(ok, phys * bs + positions % bs, NB * bs)
    pool_k = _pool_write(pool_k, flat, k)
    pool_v = _pool_write(pool_v, flat, v)
    pool_pos = _pool_write(pool_pos, flat, positions)
    # kernel routing under a serving mesh (DESIGN §12), derived from the
    # SAME rule that placed the pool (`kv_head_axes`): sharded on
    # kv-heads -> shard_map'd TP kernel; sharded on head_dim -> the
    # Pallas custom call cannot partition it (GSPMD would all-gather the
    # whole pool onto every chip), so take the gather-view fallback
    # whose jnp gathers stay sharded; replicated -> the single-device
    # kernel is safe.
    from repro.distributed.sharding import (kv_head_axes, serving_mesh,
                                            serving_model_axis)
    kv_ax = hd_ax = None
    if serving_model_axis() > 1:
        kv_ax, hd_ax = kv_head_axes(serving_mesh(), pool_k.shape[2],
                                    pool_k.shape[3])
    if T == 1 and use_pallas() and hd_ax is None:
        # paged flash-decode Pallas kernel: the kv-block grid axis walks the
        # block table (kernels/decode_attention.py, DESIGN §9)
        from repro.kernels import ops
        if kv_ax is not None:
            out = ops.paged_decode_attention_tp(
                q[:, 0], pool_k, pool_v, positions[:, 0], pool_pos, tables,
                mesh=serving_mesh(), window=window)
        else:
            out = ops.paged_decode_attention(q[:, 0], pool_k, pool_v,
                                             positions[:, 0], pool_pos,
                                             tables, window=window)
        out = out.reshape(B, 1, -1)
    else:
        kview, vview, kpos = paged_view(pool_k, pool_v, pool_pos, tables)
        out = attend(q, kview, vview, positions, kpos,
                     window=window, causal=True)
    return out @ p["wo"], pool_k, pool_v, pool_pos


def cross_attention(p, x, kv_k, kv_v, k_valid, cfg: ModelConfig, *,
                    gated: bool = False):
    """Cross-attention to fixed encoder/image keys (precomputed, no RoPE)."""
    B, T, _ = x.shape
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, T, H, hd)
    Tk = kv_k.shape[1]
    zeros_q = jnp.zeros((B, T), jnp.int32)
    k_pos = jnp.zeros((B, Tk), jnp.int32)
    out = attend(q, kv_k, kv_v, zeros_q, k_pos, causal=False, k_valid=k_valid)
    out = out @ p["wo"]
    if gated:
        out = jnp.tanh(p["gate"].astype(jnp.float32)).astype(out.dtype) * out
    return out


def cross_kv(p, enc_out, cfg: ModelConfig):
    B, S, _ = enc_out.shape
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    k = (enc_out @ p["wk"]).reshape(B, S, KV, hd)
    v = (enc_out @ p["wv"]).reshape(B, S, KV, hd)
    return k, v


# ---------------------------------------------------------------------------
# MLP (SwiGLU)


def init_mlp(key, d: int, f: int, num_layers: int, dtype):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d, f), dtype=dtype),
        "w_up": dense_init(ks[1], (d, f), dtype=dtype),
        "w_down": dense_init(ks[2], (f, d),
                             scale=0.02 / math.sqrt(2 * max(num_layers, 1)),
                             dtype=dtype),
    }


def mlp(p, x):
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


# ---------------------------------------------------------------------------
# MoE (GShard-style dense dispatch — TPU friendly, no dynamic scatter)


def init_moe(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    m = cfg.moe
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, m.num_experts), scale=0.02, dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (m.num_experts, d, m.expert_ff_dim), dtype=dtype),
        "w_up": dense_init(ks[2], (m.num_experts, d, m.expert_ff_dim), dtype=dtype),
        "w_down": dense_init(ks[3], (m.num_experts, m.expert_ff_dim, d),
                             scale=0.02 / math.sqrt(2 * cfg.num_layers), dtype=dtype),
    }
    if m.shared_ff_dim:
        p["shared"] = init_mlp(ks[4], d, m.shared_ff_dim, cfg.num_layers, dtype)
    return p


MOE_GROUP = 128  # tokens per dispatch group (GShard 'S'); bounds capacity mem


def moe_capacity(group: int, cfg: ModelConfig, no_drop: bool) -> int:
    m = cfg.moe
    if no_drop:
        return group  # worst case: every token in the group picks expert e
    c = int(math.ceil(m.num_experts_per_tok * group * m.capacity_factor
                      / m.num_experts))
    return max(c, 1)


def moe_apply(p, x, cfg: ModelConfig, *, no_drop: bool = False,
              group_size: int = MOE_GROUP):
    """x: (B, T, d) -> (y, aux_loss).

    GShard-style dense einsum dispatch over token groups of `group_size`
    (keeps the (G, E, C) dispatch tensor bounded regardless of sequence
    length). `no_drop=True` sets capacity to the exact worst case — used by
    the serving engine so chunked prefill / decode are bitwise consistent
    with the full forward pass.
    """
    B, T, d = x.shape
    m = cfg.moe
    E, K = m.num_experts, m.num_experts_per_tok

    S = B * T
    G = min(group_size, S)
    pad = (-S) % G
    xf = x.reshape(S, d)
    if pad:
        xf = jnp.concatenate([xf, jnp.zeros((pad, d), x.dtype)], axis=0)
    nG = (S + pad) // G
    xg = xf.reshape(nG, G, d)
    C = moe_capacity(G, cfg, no_drop)

    logits = (xg.astype(jnp.float32) @ p["router"])       # (nG,G,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, K)                   # (nG,G,K)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)

    # position of each (token, k) selection inside its expert's queue;
    # earlier k-choices get priority (GShard semantics)
    counts = jnp.zeros((nG, E), jnp.int32)
    dispatch = jnp.zeros((nG, G, E, C), jnp.bool_)
    combine = jnp.zeros((nG, G, E, C), jnp.float32)
    for j in range(K):
        oh = jax.nn.one_hot(idx[:, :, j], E, dtype=jnp.int32)      # (nG,G,E)
        pos = jnp.cumsum(oh, axis=1) - 1 + counts[:, None, :]      # (nG,G,E)
        keep = (pos < C) & (oh > 0)
        pos_oh = jax.nn.one_hot(pos, C, dtype=jnp.float32) * keep[..., None]
        dispatch = dispatch | (pos_oh > 0)
        combine = combine + pos_oh * gate[:, :, j, None, None]
        counts = counts + oh.sum(axis=1)

    xin = jnp.einsum("gtec,gtd->gecd", dispatch.astype(x.dtype), xg)
    h = jnp.einsum("gecd,edf->gecf", xin, p["w_gate"])
    h = jax.nn.silu(h) * jnp.einsum("gecd,edf->gecf", xin, p["w_up"])
    out_e = jnp.einsum("gecf,efd->gecd", h, p["w_down"])            # (nG,E,C,d)
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), out_e)

    y = y.reshape(nG * G, d)
    if pad:
        y = y[:S]
    y = y.reshape(B, T, d)

    if "shared" in p:
        y = y + mlp(p["shared"], x)

    # load-balance aux loss (Switch/GShard)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(idx[:, :, 0], E, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs) * m.router_aux_loss_coef
    return y, aux
