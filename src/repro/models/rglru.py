"""RG-LRU recurrent block (RecurrentGemma, arXiv:2402.19427) in pure JAX.

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
a_t = exp(-c * softplus(Lambda) * sigma(r_t)),  c = 8

Train/prefill use jax.lax.associative_scan over time; decode is one step.
The scan core is the target of kernels/rglru_scan.py.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models.layers import dense_init
from repro.models.ssm import causal_conv

_C = 8.0


def init_rglru_block(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    w = cfg.rglru.lru_width or d
    ks = jax.random.split(key, 6)
    # Lambda init so a^c in (0.9, 0.999) roughly (paper appendix)
    u = jax.random.uniform(ks[4], (w,), minval=0.9 ** 2, maxval=0.999 ** 2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^-1(-log(u)/c)
    return {
        "w_x": dense_init(ks[0], (d, w), dtype=dtype),        # recurrent branch in
        "w_gate_branch": dense_init(ks[1], (d, w), dtype=dtype),  # gelu branch
        "conv_w": dense_init(ks[2], (cfg.rglru.conv_width, w), scale=0.2,
                             dtype=dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_a": dense_init(ks[3], (w, w), scale=0.02, dtype=dtype),  # recurrence gate
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_i": dense_init(ks[5], (w, w), scale=0.02, dtype=dtype),  # input gate
        "b_i": jnp.zeros((w,), jnp.float32),
        "lam": lam.astype(jnp.float32),
        "w_out": dense_init(jax.random.fold_in(key, 7), (w, d),
                            scale=0.02 / math.sqrt(2 * cfg.num_layers),
                            dtype=dtype),
    }


def rglru_scan(a, bx, h0=None):
    """First-order linear recurrence via associative scan.

    a, bx: (B, T, W) fp32; h_t = a_t h_{t-1} + bx_t. Returns (h_all, h_T)."""
    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, a_r * b_l + b_r

    if h0 is not None:
        bx = bx.at[:, 0].add(a[:, 0] * h0)
    _, h_all = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h_all, h_all[:, -1]


def rglru_core(p, x, *, h0=None, decode: bool = False):
    """x: (B, T, W) post-conv activations. Returns (y, h_T) in fp32 state."""
    f32 = jnp.float32
    r = jax.nn.sigmoid((x @ p["w_a"]).astype(f32) + p["b_a"])
    i = jax.nn.sigmoid((x @ p["w_i"]).astype(f32) + p["b_i"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r              # (B,T,W)
    a = jnp.exp(log_a)
    gated_x = i * x.astype(f32)
    # multiply by sqrt(1 - a^2) for variance preservation
    bx = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-9)) * gated_x
    if decode:
        h0 = jnp.zeros_like(bx[:, 0]) if h0 is None else h0
        h = a[:, 0] * h0 + bx[:, 0]
        return h[:, None].astype(x.dtype), h
    h_all, h_last = rglru_scan(a, bx, h0)
    return h_all.astype(x.dtype), h_last


def rglru_block(p, u, cfg: ModelConfig, *, conv_state=None, rec_state=None,
                decode: bool = False):
    """Full RecurrentGemma recurrent block. u: (B, T, d).

    Returns (out, (conv_state, rec_state))."""
    gate = jax.nn.gelu((u @ p["w_gate_branch"]).astype(jnp.float32)).astype(u.dtype)
    x = u @ p["w_x"]
    x, conv_state = causal_conv(x, p["conv_w"], p["conv_b"], conv_state)
    y, rec_state = rglru_core(p, x, h0=rec_state, decode=decode)
    return (y * gate) @ p["w_out"], (conv_state, rec_state)
