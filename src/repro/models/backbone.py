"""Backbone assembly for all six architecture families.

Design notes
------------
* Layer stacks are parameter-stacked (leading L axis) and driven by
  jax.lax.scan so the HLO is O(1) in depth — this keeps the 80 dry-run
  compiles tractable and matches production practice (MaxText-style).
* A single cached-attention code path serves chunked prefill AND decode
  (decode = chunk of length 1). Caches store absolute positions per slot,
  so ring-buffer (sliding-window) and full caches share all code.
* Padding tokens carry position -1; their cache writes are dropped via
  out-of-bounds scatter (mode='drop').
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ArchFamily, AttentionKind, ModelConfig
from repro.models import layers as L
from repro.models import rglru as R
from repro.models import ssm as S

Params = Dict[str, Any]
Cache = Dict[str, Any]


def cfg_dtype(cfg: ModelConfig, override=None):
    if override is not None:
        return override
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


def _stack_init(init_fn, key, n: int):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def window_of(cfg: ModelConfig) -> int:
    if cfg.attention == AttentionKind.SLIDING:
        return cfg.sliding_window
    if cfg.attention == AttentionKind.LOCAL_HYBRID:
        return cfg.rglru.window_size
    return 0


def phys_cache_len(cfg: ModelConfig, max_context: int, chunk: int = 1) -> int:
    """Ring capacity for windowed attention: a chunk of T queries written
    before attending must still see window-1 keys behind its OLDEST query,
    so the ring holds window + chunk - 1 positions (chunk=1 decode -> just
    the window)."""
    w = window_of(cfg)
    return min(max_context, w + chunk - 1) if w else max_context


# ---------------------------------------------------------------------------
# per-layer blocks (single-layer params)


def _dense_layer_init(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "attn": L.init_attention(ks[0], cfg, dtype),
        "mlp": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.num_layers, dtype),
    }


def _moe_layer_init(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "attn": L.init_attention(ks[0], cfg, dtype),
        "moe": L.init_moe(ks[1], cfg, dtype),
    }


def _cross_layer_init(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "attn": L.init_attention(ks[0], cfg, dtype, cross=True),
        "mlp": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.num_layers, dtype),
    }


def _ssm_layer_init(key, cfg: ModelConfig, dtype):
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "mixer": S.init_mamba2_block(key, cfg, dtype),
    }


def _rec_layer_init(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "rec": R.init_rglru_block(ks[0], cfg, dtype),
        "mlp": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.num_layers, dtype),
    }


def _attn_block_train(p, x, positions, cfg, window, no_drop=False):
    h = L.rms_norm(x, p["ln1"], cfg.rms_eps)
    x = x + L.self_attention_train(p["attn"], h, positions, cfg, window=window)
    h = L.rms_norm(x, p["ln2"], cfg.rms_eps)
    if "moe" in p:
        y, aux = L.moe_apply(p["moe"], h, cfg, no_drop=no_drop)
        return x + y, aux
    return x + L.mlp(p["mlp"], h), 0.0


def _attn_block_cached(p, x, positions, ck, cv, cpos, cfg, window):
    h = L.rms_norm(x, p["ln1"], cfg.rms_eps)
    a, ck, cv, cpos = L.self_attention_cached(
        p["attn"], h, positions, ck, cv, cpos, cfg, window=window)
    x = x + a
    h = L.rms_norm(x, p["ln2"], cfg.rms_eps)
    if "moe" in p:
        # no_drop: serving must be chunking-invariant (see moe_apply docs)
        y, _ = L.moe_apply(p["moe"], h, cfg,
                           no_drop=cfg.moe.inference_no_drop)
        x = x + y
    else:
        x = x + L.mlp(p["mlp"], h)
    return x, ck, cv, cpos


def _attn_block_paged(p, x, positions, ck, cv, cpos, tables, cfg, window):
    """Paged twin of `_attn_block_cached`: K/V go through the block-table
    indexed physical pools instead of a per-slot contiguous row (DESIGN §9)."""
    h = L.rms_norm(x, p["ln1"], cfg.rms_eps)
    a, ck, cv, cpos = L.self_attention_paged(
        p["attn"], h, positions, ck, cv, cpos, tables, cfg, window=window)
    x = x + a
    h = L.rms_norm(x, p["ln2"], cfg.rms_eps)
    if "moe" in p:
        y, _ = L.moe_apply(p["moe"], h, cfg,
                           no_drop=cfg.moe.inference_no_drop)
        x = x + y
    else:
        x = x + L.mlp(p["mlp"], h)
    return x, ck, cv, cpos


def _cross_block(p, x, kv_k, kv_v, k_valid, cfg, gated):
    h = L.rms_norm(x, p["ln1"], cfg.rms_eps)
    x = x + L.cross_attention(p["attn"], h, kv_k, kv_v, k_valid, cfg, gated=gated)
    h = L.rms_norm(x, p["ln2"], cfg.rms_eps)
    return x + L.mlp(p["mlp"], h)


def _ssm_block(p, x, cfg, conv_state=None, ssm_state=None, decode=False):
    h = L.rms_norm(x, p["ln1"], cfg.rms_eps)
    y, (conv_state, ssm_state) = S.mamba2_block(
        p["mixer"], h, cfg, conv_state=conv_state, ssm_state=ssm_state,
        decode=decode)
    return x + y, conv_state, ssm_state


def _rec_block(p, x, cfg, conv_state=None, rec_state=None, decode=False):
    h = L.rms_norm(x, p["ln1"], cfg.rms_eps)
    y, (conv_state, rec_state) = R.rglru_block(
        p["rec"], h, cfg, conv_state=conv_state, rec_state=rec_state,
        decode=decode)
    x = x + y
    h = L.rms_norm(x, p["ln2"], cfg.rms_eps)
    return x + L.mlp(p["mlp"], h), conv_state, rec_state


# ---------------------------------------------------------------------------
# init for the whole model


def init_params(key, cfg: ModelConfig, dtype=None) -> Params:
    dt = cfg_dtype(cfg, dtype)
    ks = jax.random.split(key, 8)
    p: Params = {
        "embed": L.dense_init(ks[0], (cfg.vocab_size, cfg.d_model),
                              scale=0.02, dtype=dt),
        "ln_f": jnp.zeros((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(ks[1], (cfg.d_model, cfg.vocab_size),
                                    dtype=dt)
    fam = cfg.family
    if fam in (ArchFamily.DENSE,):
        p["layers"] = _stack_init(
            lambda k: _dense_layer_init(k, cfg, dt), ks[2], cfg.num_layers)
    elif fam == ArchFamily.MOE:
        p["layers"] = _stack_init(
            lambda k: _moe_layer_init(k, cfg, dt), ks[2], cfg.num_layers)
    elif fam == ArchFamily.SSM:
        p["layers"] = _stack_init(
            lambda k: _ssm_layer_init(k, cfg, dt), ks[2], cfg.num_layers)
    elif fam == ArchFamily.HYBRID:
        kinds = cfg.layer_kinds()
        n_rec = sum(1 for k in kinds if k == "recurrent")
        n_att = len(kinds) - n_rec
        p["rec_layers"] = _stack_init(
            lambda k: _rec_layer_init(k, cfg, dt), ks[2], n_rec)
        p["att_layers"] = _stack_init(
            lambda k: _dense_layer_init(k, cfg, dt), ks[3], n_att)
    elif fam == ArchFamily.VLM:
        p["layers"] = _stack_init(
            lambda k: _dense_layer_init(k, cfg, dt), ks[2], cfg.num_layers)
        p["cross_layers"] = _stack_init(
            lambda k: _cross_layer_init(k, cfg, dt), ks[3], cfg.num_cross_layers)
    elif fam == ArchFamily.ENCDEC:
        p["enc_layers"] = _stack_init(
            lambda k: _dense_layer_init(k, cfg, dt), ks[2], cfg.encoder_layers)
        p["dec_layers"] = _stack_init(
            lambda k: _dense_layer_init(k, cfg, dt), ks[3], cfg.num_layers)
        p["dec_cross"] = _stack_init(
            lambda k: _cross_layer_init(k, cfg, dt), ks[4], cfg.num_layers)
    else:
        raise ValueError(fam)
    return p


def logits_head(p, x, cfg: ModelConfig):
    h = L.rms_norm(x, p["ln_f"], cfg.rms_eps)
    wout = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    return (h @ wout).astype(jnp.float32)


# ---------------------------------------------------------------------------
# TRAIN forward (full sequence, no cache)


def _scan_layers(body, x, stacked, remat: bool, init_aux=0.0):
    if remat:
        body = jax.checkpoint(body)

    def f(carry, lp):
        return body(carry, lp), None

    (x, aux), _ = jax.lax.scan(f, (x, init_aux), stacked)
    return x, aux


def forward_train(p: Params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
                  *, remat: bool = True, no_drop: bool = False,
                  return_hidden: bool = False
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logits (B,T,V) fp32, aux_loss scalar); with
    return_hidden=True returns the pre-head hidden states (B,T,d) instead of
    logits (the chunked-loss path never materializes (B,T,V) — §Perf B)."""
    tokens = batch["tokens"]
    B, T = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    x = p["embed"][tokens]
    fam = cfg.family
    win = window_of(cfg)

    if fam in (ArchFamily.DENSE, ArchFamily.MOE):
        def body(carry, lp):
            h, aux = carry
            h, a = _attn_block_train(lp, h, positions, cfg, win, no_drop)
            return h, aux + a
        x, aux = _scan_layers(body, x, p["layers"], remat)

    elif fam == ArchFamily.SSM:
        def body(carry, lp):
            h, aux = carry
            h, _, _ = _ssm_block(lp, h, cfg)
            return h, aux
        x, aux = _scan_layers(body, x, p["layers"], remat)

    elif fam == ArchFamily.HYBRID:
        x, aux = _hybrid_train(p, x, positions, cfg, remat)

    elif fam == ArchFamily.VLM:
        x, aux = _vlm_train(p, x, positions, batch["images"], cfg, remat)

    elif fam == ArchFamily.ENCDEC:
        x, aux = _encdec_train(p, x, positions, batch, cfg, remat)
    else:
        raise ValueError(fam)

    if return_hidden:
        return x, aux
    return logits_head(p, x, cfg), aux


def _hybrid_train(p, x, positions, cfg, remat):
    kinds = cfg.layer_kinds()
    pat = cfg.rglru.block_pattern
    n_pat = len(pat)
    n_groups = cfg.num_layers // n_pat
    rec_per_group = sum(1 for k in pat if k == "recurrent")
    att_per_group = n_pat - rec_per_group
    win = cfg.rglru.window_size

    rec_grouped = jax.tree.map(
        lambda a: a[: n_groups * rec_per_group].reshape(
            (n_groups, rec_per_group) + a.shape[1:]), p["rec_layers"])
    att_grouped = jax.tree.map(
        lambda a: a[: n_groups * att_per_group].reshape(
            (n_groups, att_per_group) + a.shape[1:]), p["att_layers"])

    def group_body(carry, lp):
        h, aux = carry
        rec_p, att_p = lp
        ri = ai = 0
        for k in pat:
            if k == "recurrent":
                one = jax.tree.map(lambda a: a[ri], rec_p)
                h, _, _ = _rec_block(one, h, cfg)
                ri += 1
            else:
                one = jax.tree.map(lambda a: a[ai], att_p)
                h, _ = _attn_block_train(one, h, positions, cfg, win)
                ai += 1
        return h, aux

    body = jax.checkpoint(group_body) if remat else group_body

    def f(carry, lp):
        return body(carry, lp), None

    (x, aux), _ = jax.lax.scan(f, (x, 0.0), (rec_grouped, att_grouped))

    # leftover layers (pattern remainder), unrolled
    used_rec = n_groups * rec_per_group
    used_att = n_groups * att_per_group
    ri, ai = used_rec, used_att
    for k in kinds[n_groups * n_pat:]:
        if k == "recurrent":
            one = jax.tree.map(lambda a: a[ri], p["rec_layers"])
            x, _, _ = _rec_block(one, x, cfg)
            ri += 1
        else:
            one = jax.tree.map(lambda a: a[ai], p["att_layers"])
            x, _ = _attn_block_train(one, x, positions, cfg, win)
            ai += 1
    return x, aux


def _vlm_train(p, x, positions, images, cfg, remat):
    """images: (B, P, d) stub patch embeddings. Cross layer every
    `vlm_cross_every` self layers."""
    n_cross = cfg.num_cross_layers
    per = cfg.num_layers // n_cross
    self_grouped = jax.tree.map(
        lambda a: a.reshape((n_cross, per) + a.shape[1:]), p["layers"])
    kv = jax.vmap(lambda cp: L.cross_kv(cp["attn"], images, cfg))(
        p["cross_layers"])  # (Lc, B, P, KV, hd) x2

    def group_body(carry, lp):
        h, aux = carry
        self_p, cross_p, (ck, cv) = lp

        def inner(c, one):
            hh, ax = c
            hh, a = _attn_block_train(one, hh, positions, cfg, 0)
            return (hh, ax + a), None

        (h, aux), _ = jax.lax.scan(inner, (h, aux), self_p)
        h = _cross_block(cross_p, h, ck, cv, None, cfg, gated=True)
        return h, aux

    body = jax.checkpoint(group_body) if remat else group_body

    def f(carry, lp):
        return body(carry, lp), None

    (x, aux), _ = jax.lax.scan(
        f, (x, 0.0), (self_grouped, p["cross_layers"], kv))
    return x, aux


def encode(p, enc_frames, cfg: ModelConfig, remat: bool = False):
    """Bidirectional encoder over stub frame embeddings (B, S, d)."""
    B, Senc, _ = enc_frames.shape
    pos = jnp.broadcast_to(jnp.arange(Senc, dtype=jnp.int32)[None], (B, Senc))

    def body(carry, lp):
        h, aux = carry
        hh = L.rms_norm(h, lp["ln1"], cfg.rms_eps)
        q, k, v = L.attention_qkv(lp["attn"], hh, cfg)
        q = L.apply_rope(q, pos, cfg.rope_theta)
        k = L.apply_rope(k, pos, cfg.rope_theta)
        att = L.attend(q, k, v, pos, pos, causal=False)
        h = h + att @ lp["attn"]["wo"]
        hh = L.rms_norm(h, lp["ln2"], cfg.rms_eps)
        return h + L.mlp(lp["mlp"], hh), aux

    x, _ = _scan_layers(body, enc_frames, p["enc_layers"], remat)
    return x


def _encdec_train(p, x, positions, batch, cfg, remat):
    enc_out = encode(p, batch["enc_frames"], cfg, remat)
    kv = jax.vmap(lambda cp: L.cross_kv(cp["attn"], enc_out, cfg))(
        p["dec_cross"])

    def body(carry, lp):
        h, aux = carry
        dec_p, cross_p, (ck, cv) = lp
        h, a = _attn_block_train(dec_p, h, positions, cfg, 0)
        h = _cross_block(cross_p, h, ck, cv, None, cfg, gated=False)
        return h, aux + a

    bodyf = jax.checkpoint(body) if remat else body

    def f(carry, lp):
        return bodyf(carry, lp), None

    (x, aux), _ = jax.lax.scan(
        f, (x, 0.0), (p["dec_layers"], p["dec_cross"], kv))
    return x, aux


# ---------------------------------------------------------------------------
# caches


def init_cache(cfg: ModelConfig, batch: int, max_context: int,
               dtype=None, enc_len: int = 0, chunk: int = 1) -> Cache:
    dt = cfg_dtype(cfg, dtype)
    hd = cfg.resolved_head_dim
    KV = cfg.num_kv_heads
    S = phys_cache_len(cfg, max_context, chunk)
    fam = cfg.family
    c: Cache = {}
    if fam in (ArchFamily.DENSE, ArchFamily.MOE, ArchFamily.VLM,
               ArchFamily.ENCDEC):
        Ldec = cfg.num_layers
        c["k"] = jnp.zeros((Ldec, batch, S, KV, hd), dt)
        c["v"] = jnp.zeros((Ldec, batch, S, KV, hd), dt)
        c["pos"] = jnp.full((batch, S), -1, jnp.int32)
    if fam == ArchFamily.VLM:
        # cross KV filled at prefill from image embeddings
        c["cross_k"] = jnp.zeros(
            (cfg.num_cross_layers, batch, enc_len, KV, hd), dt)
        c["cross_v"] = jnp.zeros_like(c["cross_k"])
    if fam == ArchFamily.ENCDEC:
        c["cross_k"] = jnp.zeros((cfg.num_layers, batch, enc_len, KV, hd), dt)
        c["cross_v"] = jnp.zeros_like(c["cross_k"])
    if fam == ArchFamily.SSM:
        d_in, H, P, N = S_dims_of(cfg)
        conv_ch = d_in + 2 * N
        c["conv"] = jnp.zeros(
            (cfg.num_layers, batch, cfg.ssm.conv_width - 1, conv_ch), dt)
        c["ssm"] = jnp.zeros((cfg.num_layers, batch, H, P, N), jnp.float32)
    if fam == ArchFamily.HYBRID:
        kinds = cfg.layer_kinds()
        n_rec = sum(1 for k in kinds if k == "recurrent")
        n_att = len(kinds) - n_rec
        w = cfg.rglru.lru_width or cfg.d_model
        c["k"] = jnp.zeros((n_att, batch, S, KV, hd), dt)
        c["v"] = jnp.zeros((n_att, batch, S, KV, hd), dt)
        c["pos"] = jnp.full((batch, S), -1, jnp.int32)
        c["conv"] = jnp.zeros(
            (n_rec, batch, cfg.rglru.conv_width - 1, w), dt)
        c["rec"] = jnp.zeros((n_rec, batch, w), jnp.float32)
    return c


def init_paged_cache(cfg: ModelConfig, n_slots: int, num_blocks: int,
                     block_size: int, dtype=None, enc_len: int = 0) -> Cache:
    """Physically paged serving cache (DESIGN §9).

    Attention K/V live in (layers, num_blocks, block_size, KV, hd) pools
    shared by every request and indexed through per-request block tables
    (the BlockManager's tables ARE the storage map); `pos` is the pool-wide
    (num_blocks, block_size) absolute-position map (-1 = empty slot).
    Constant-size per-request state (SSM conv/ssm, RG-LRU conv/rec,
    cross-KV) stays per-slot with `n_slots` rows, pinned to a request for
    its whole life so lane promotion / eviction never copy it."""
    dt = cfg_dtype(cfg, dtype)
    hd = cfg.resolved_head_dim
    KV = cfg.num_kv_heads
    fam = cfg.family
    c: Cache = {}
    if fam in (ArchFamily.DENSE, ArchFamily.MOE, ArchFamily.VLM,
               ArchFamily.ENCDEC):
        Ldec = cfg.num_layers
        c["k"] = jnp.zeros((Ldec, num_blocks, block_size, KV, hd), dt)
        c["v"] = jnp.zeros((Ldec, num_blocks, block_size, KV, hd), dt)
        c["pos"] = jnp.full((num_blocks, block_size), -1, jnp.int32)
    if fam == ArchFamily.VLM:
        c["cross_k"] = jnp.zeros(
            (cfg.num_cross_layers, n_slots, enc_len, KV, hd), dt)
        c["cross_v"] = jnp.zeros_like(c["cross_k"])
    if fam == ArchFamily.ENCDEC:
        c["cross_k"] = jnp.zeros((cfg.num_layers, n_slots, enc_len, KV, hd), dt)
        c["cross_v"] = jnp.zeros_like(c["cross_k"])
    if fam == ArchFamily.SSM:
        d_in, H, P, N = S_dims_of(cfg)
        conv_ch = d_in + 2 * N
        c["conv"] = jnp.zeros(
            (cfg.num_layers, n_slots, cfg.ssm.conv_width - 1, conv_ch), dt)
        c["ssm"] = jnp.zeros((cfg.num_layers, n_slots, H, P, N), jnp.float32)
    if fam == ArchFamily.HYBRID:
        kinds = cfg.layer_kinds()
        n_rec = sum(1 for k in kinds if k == "recurrent")
        n_att = len(kinds) - n_rec
        w = cfg.rglru.lru_width or cfg.d_model
        c["k"] = jnp.zeros((n_att, num_blocks, block_size, KV, hd), dt)
        c["v"] = jnp.zeros((n_att, num_blocks, block_size, KV, hd), dt)
        c["pos"] = jnp.full((num_blocks, block_size), -1, jnp.int32)
        c["conv"] = jnp.zeros(
            (n_rec, n_slots, cfg.rglru.conv_width - 1, w), dt)
        c["rec"] = jnp.zeros((n_rec, n_slots, w), jnp.float32)
    return c


def S_dims_of(cfg):
    return S.ssm_dims(cfg)


def cache_bytes(cfg: ModelConfig, batch: int, max_context: int,
                enc_len: int = 0) -> int:
    cache = jax.eval_shape(
        lambda: init_cache(cfg, batch, max_context, enc_len=enc_len))
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))


# ---------------------------------------------------------------------------
# PREFILL / DECODE (unified chunked step; decode = chunk of length 1)


def forward_cached(p: Params, tokens, positions, cache: Cache,
                   cfg: ModelConfig, *, decode: bool,
                   extras: Optional[Dict[str, jnp.ndarray]] = None,
                   last_only: bool = False,
                   tables=None) -> Tuple[jnp.ndarray, Cache]:
    """tokens: (B, T) int32; positions: (B, T) absolute, -1 for padding.

    Returns (logits (B, T, V) fp32, updated cache). For SSM/recurrent layers
    `decode=True` selects the O(1) step (requires T == 1).
    last_only: compute the vocab projection for the final position only
    (production serving path — avoids materializing (B, T, V); §Perf iter A).
    tables: optional (B, MB) per-request physical block tables; when given,
    the cache's k/v/pos are the paged pools of `init_paged_cache` and all
    attention layers read/write through the tables (DESIGN §9).
    """
    extras = extras or {}
    x = p["embed"][tokens]
    fam = cfg.family
    win = window_of(cfg)
    new_cache = dict(cache)

    if fam in (ArchFamily.DENSE, ArchFamily.MOE):
        x, new_cache = _attn_stack_cached(
            p["layers"], x, positions, cache, cfg, win, new_cache,
            tables=tables)

    elif fam == ArchFamily.SSM:
        def body(carry, lp):
            h = carry
            one, conv_s, ssm_s = lp
            h, conv_s, ssm_s = _ssm_block(
                one, h, cfg, conv_state=conv_s, ssm_state=ssm_s, decode=decode)
            return h, (conv_s, ssm_s)

        x, (conv_n, ssm_n) = jax.lax.scan(
            body, x, (p["layers"], cache["conv"], cache["ssm"]))
        new_cache["conv"], new_cache["ssm"] = conv_n, ssm_n

    elif fam == ArchFamily.HYBRID:
        x, new_cache = _hybrid_cached(p, x, positions, cache, cfg, decode,
                                      tables=tables)

    elif fam == ArchFamily.VLM:
        if "images" in extras:  # prefill: compute cross KV once
            kv_k, kv_v = jax.vmap(
                lambda cp: L.cross_kv(cp["attn"], extras["images"], cfg))(
                p["cross_layers"])
            new_cache["cross_k"], new_cache["cross_v"] = kv_k, kv_v
        x, new_cache = _vlm_cached(p, x, positions, new_cache, cfg,
                                   tables=tables)

    elif fam == ArchFamily.ENCDEC:
        if "enc_frames" in extras:  # prefill: run encoder, fill cross KV
            enc_out = encode(p, extras["enc_frames"], cfg)
            kv_k, kv_v = jax.vmap(
                lambda cp: L.cross_kv(cp["attn"], enc_out, cfg))(
                p["dec_cross"])
            new_cache["cross_k"], new_cache["cross_v"] = kv_k, kv_v
        x, new_cache = _encdec_cached(p, x, positions, new_cache, cfg,
                                      tables=tables)
    else:
        raise ValueError(fam)

    if last_only:
        x = x[:, -1:]
    return logits_head(p, x, cfg), new_cache


def _attn_stack_cached(stacked, x, positions, cache, cfg, win, new_cache,
                       tables=None):
    """Layer loop for the cached (serving) path.

    Uses fori_loop with dynamic_update_index on a loop-CARRIED cache rather
    than scan xs/ys: scan rebuilds the stacked (L,B,S,KV,hd) cache as fresh
    ys output (2-3x full-cache temp traffic per step); a while-loop carry
    lets XLA update the (donated) buffer in place (§Perf iteration E).
    With `tables` the per-layer k/v are the paged pools (DESIGN §9)."""
    cpos0 = cache["pos"]
    L = cache["k"].shape[0]

    def body(i, carry):
        h, k_all, v_all, cpos = carry
        lp = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
            stacked)
        ck = jax.lax.dynamic_index_in_dim(k_all, i, 0, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(v_all, i, 0, keepdims=False)
        if tables is None:
            h, ck, cv, cpos = _attn_block_cached(
                lp, h, positions, ck, cv, cpos0, cfg, win)
        else:
            h, ck, cv, cpos = _attn_block_paged(
                lp, h, positions, ck, cv, cpos0, tables, cfg, win)
        k_all = jax.lax.dynamic_update_index_in_dim(k_all, ck, i, 0)
        v_all = jax.lax.dynamic_update_index_in_dim(v_all, cv, i, 0)
        return (h, k_all, v_all, cpos)

    x, k_n, v_n, cpos = jax.lax.fori_loop(
        0, L, body, (x, cache["k"], cache["v"], cpos0))
    new_cache["k"], new_cache["v"], new_cache["pos"] = k_n, v_n, cpos
    return x, new_cache


def _hybrid_cached(p, x, positions, cache, cfg, decode, tables=None):
    """fori_loop over the heterogeneous layer pattern with in-place cache
    carry (§Perf iter E). Static index maps translate the flat layer index
    into the recurrent-stack / attention-stack positions; lax.cond picks
    the branch (both return the full same-shape carry). With `tables` the
    attention branch goes through the paged pools (DESIGN §9)."""
    import numpy as np
    kinds = cfg.layer_kinds()
    win = cfg.rglru.window_size
    cpos0 = cache["pos"]
    is_att = jnp.asarray(np.array([k == "attention" for k in kinds]))
    rec_of = jnp.asarray(np.cumsum([k == "recurrent" for k in kinds]) - 1)
    att_of = jnp.asarray(np.cumsum([k == "attention" for k in kinds]) - 1)

    def take(t, j):
        return jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, j, 0, keepdims=False), t)

    def body(i, carry):
        h, k_all, v_all, cpos, conv_all, rec_all = carry

        def att_branch(args):
            h, k_all, v_all, cpos, conv_all, rec_all = args
            j = att_of[i]
            one = take(p["att_layers"], j)
            ck = jax.lax.dynamic_index_in_dim(k_all, j, 0, keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(v_all, j, 0, keepdims=False)
            if tables is None:
                h, ck, cv, cpos = _attn_block_cached(
                    one, h, positions, ck, cv, cpos0, cfg, win)
            else:
                h, ck, cv, cpos = _attn_block_paged(
                    one, h, positions, ck, cv, cpos0, tables, cfg, win)
            k_all = jax.lax.dynamic_update_index_in_dim(k_all, ck, j, 0)
            v_all = jax.lax.dynamic_update_index_in_dim(v_all, cv, j, 0)
            return (h, k_all, v_all, cpos, conv_all, rec_all)

        def rec_branch(args):
            h, k_all, v_all, cpos, conv_all, rec_all = args
            j = rec_of[i]
            one = take(p["rec_layers"], j)
            conv_s = jax.lax.dynamic_index_in_dim(conv_all, j, 0,
                                                  keepdims=False)
            rec_s = jax.lax.dynamic_index_in_dim(rec_all, j, 0,
                                                 keepdims=False)
            h, conv_s, rec_s = _rec_block(
                one, h, cfg, conv_state=conv_s, rec_state=rec_s,
                decode=decode)
            conv_all = jax.lax.dynamic_update_index_in_dim(
                conv_all, conv_s, j, 0)
            rec_all = jax.lax.dynamic_update_index_in_dim(
                rec_all, rec_s, j, 0)
            return (h, k_all, v_all, cpos, conv_all, rec_all)

        return jax.lax.cond(is_att[i], att_branch, rec_branch, carry)

    carry = (x, cache["k"], cache["v"], cpos0, cache["conv"], cache["rec"])
    x, k_n, v_n, cpos, conv_n, rec_n = jax.lax.fori_loop(
        0, len(kinds), body, carry)
    new_cache = dict(cache)
    new_cache.update(k=k_n, v=v_n, pos=cpos, conv=conv_n, rec=rec_n)
    return x, new_cache


def _vlm_cached(p, x, positions, cache, cfg, tables=None):
    """fori_loop with in-place cache carry (§Perf iter E); a cross-attn
    layer fires after every `per` self layers via lax.cond. With `tables`
    self-attention goes through the paged pools (DESIGN §9)."""
    n_cross = cfg.num_cross_layers
    per = cfg.num_layers // n_cross
    cpos0 = cache["pos"]
    L = cfg.num_layers

    def body(i, carry):
        h, k_all, v_all, cpos = carry
        lp = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
            p["layers"])
        ck = jax.lax.dynamic_index_in_dim(k_all, i, 0, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(v_all, i, 0, keepdims=False)
        if tables is None:
            h, ck, cv, cpos = _attn_block_cached(
                lp, h, positions, ck, cv, cpos0, cfg, 0)
        else:
            h, ck, cv, cpos = _attn_block_paged(
                lp, h, positions, ck, cv, cpos0, tables, cfg, 0)
        k_all = jax.lax.dynamic_update_index_in_dim(k_all, ck, i, 0)
        v_all = jax.lax.dynamic_update_index_in_dim(v_all, cv, i, 0)

        def with_cross(hh):
            j = i // per
            cp = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, j, 0,
                                                       keepdims=False),
                p["cross_layers"])
            xk = jax.lax.dynamic_index_in_dim(cache["cross_k"], j, 0,
                                              keepdims=False)
            xv = jax.lax.dynamic_index_in_dim(cache["cross_v"], j, 0,
                                              keepdims=False)
            return _cross_block(cp, hh, xk, xv, None, cfg, gated=True)

        h = jax.lax.cond((i + 1) % per == 0, with_cross, lambda hh: hh, h)
        return (h, k_all, v_all, cpos)

    x, k_n, v_n, cpos = jax.lax.fori_loop(
        0, L, body, (x, cache["k"], cache["v"], cpos0))
    cache = dict(cache)
    cache["k"], cache["v"], cache["pos"] = k_n, v_n, cpos
    return x, cache


def _encdec_cached(p, x, positions, cache, cfg, tables=None):
    """fori_loop with in-place self-KV cache carry (§Perf iter E); with
    `tables` decoder self-attention goes through the paged pools
    (DESIGN §9)."""
    cpos0 = cache["pos"]

    def body(i, carry):
        h, k_all, v_all, cpos = carry
        take = lambda t: jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False), t)
        dec_p = take(p["dec_layers"])
        cross_p = take(p["dec_cross"])
        ck = jax.lax.dynamic_index_in_dim(k_all, i, 0, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(v_all, i, 0, keepdims=False)
        xk = jax.lax.dynamic_index_in_dim(cache["cross_k"], i, 0,
                                          keepdims=False)
        xv = jax.lax.dynamic_index_in_dim(cache["cross_v"], i, 0,
                                          keepdims=False)
        if tables is None:
            h, ck, cv, cpos = _attn_block_cached(
                dec_p, h, positions, ck, cv, cpos0, cfg, 0)
        else:
            h, ck, cv, cpos = _attn_block_paged(
                dec_p, h, positions, ck, cv, cpos0, tables, cfg, 0)
        h = _cross_block(cross_p, h, xk, xv, None, cfg, gated=False)
        k_all = jax.lax.dynamic_update_index_in_dim(k_all, ck, i, 0)
        v_all = jax.lax.dynamic_update_index_in_dim(v_all, cv, i, 0)
        return (h, k_all, v_all, cpos)

    x, k_n, v_n, cpos = jax.lax.fori_loop(
        0, cfg.num_layers, body, (x, cache["k"], cache["v"], cpos0))
    cache = dict(cache)
    cache["k"], cache["v"], cache["pos"] = k_n, v_n, cpos
    return x, cache
