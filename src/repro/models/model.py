"""Public model facade: init / train loss / prefill / decode, per config."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ArchFamily, InputShape, ModelConfig
from repro.models import backbone as B

# stub-frontend lengths (assignment carve-out: modality encoders are stubs)
AUDIO_FRAMES = 1024     # seamless-m4t: precomputed conv/mel frame embeddings
IMAGE_PATCHES = 1601    # llama-3.2-vision: 1 tile of 1600 patches + CLS


class Model:
    """Thin, stateless facade bound to a ModelConfig."""

    def __init__(self, cfg: ModelConfig, dtype=None):
        self.cfg = cfg
        self.dtype = dtype

    # -- params -----------------------------------------------------------
    def init(self, key) -> Dict[str, Any]:
        return B.init_params(key, self.cfg, self.dtype)

    def init_shapes(self) -> Dict[str, Any]:
        return jax.eval_shape(lambda k: B.init_params(k, self.cfg, self.dtype),
                              jax.random.PRNGKey(0))

    # -- training ---------------------------------------------------------
    def forward_train(self, params, batch, remat: bool = True,
                      no_drop: bool = False):
        return B.forward_train(params, batch, self.cfg, remat=remat,
                               no_drop=no_drop)

    def loss_fn(self, params, batch, remat: bool = True,
                loss_chunk: int = 512
                ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        """Next-token CE, computed over T-chunks so the full (B, T, V) fp32
        logits tensor is never materialized (§Perf iteration B — at 256k
        vocab x 4k seq that tensor is TBs/device)."""
        hidden, aux = B.forward_train(params, batch, self.cfg, remat=remat,
                                      return_hidden=True)
        tokens = batch["tokens"]
        h = hidden[:, :-1]
        targets = tokens[:, 1:]
        mask = batch.get("loss_mask")
        w = mask[:, 1:].astype(jnp.float32) if mask is not None \
            else jnp.ones(targets.shape, jnp.float32)

        Bs, Tm, d = h.shape
        C = min(loss_chunk, Tm)
        pad = (-Tm) % C
        if pad:
            h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
            targets = jnp.pad(targets, ((0, 0), (0, pad)))
            w = jnp.pad(w, ((0, 0), (0, pad)))
        nc = (Tm + pad) // C
        hc = h.reshape(Bs, nc, C, d).swapaxes(0, 1)        # (nc, B, C, d)
        tc = targets.reshape(Bs, nc, C).swapaxes(0, 1)
        wc = w.reshape(Bs, nc, C).swapaxes(0, 1)

        def chunk_ce(args):
            # CE via logsumexp + one-hot contraction: both reduce OVER the
            # (model-sharded) vocab axis, so the (B, C, V) logits stay
            # sharded. take_along_axis (a gather over the sharded axis)
            # made GSPMD replicate the whole chunk (§Perf iteration F).
            hh, tt, ww = args
            lg = B.logits_head(params, hh, self.cfg)       # (B, C, V) fp32
            # pin (batch x fsdp, :, vocab x model) — scan-transpose loses
            # the batch sharding on the cotangent otherwise (§Perf iter F)
            from repro.distributed.sharding import maybe_constrain
            lg = maybe_constrain(lg, ("pod", "data"), None, "model")
            lse = jax.scipy.special.logsumexp(lg, axis=-1)
            onehot = jax.nn.one_hot(tt, lg.shape[-1], dtype=lg.dtype)
            tgt = jnp.einsum("bcv,bcv->bc", lg, onehot)
            nll = lse - tgt
            return (nll * ww).sum()

        total = jax.lax.map(chunk_ce, (hc, tc, wc)).sum()
        ce = total / jnp.clip(w.sum(), 1.0)
        return ce + aux, {"ce": ce, "aux": aux}

    # -- serving ----------------------------------------------------------
    def init_cache(self, batch: int, max_context: int, enc_len: int = 0,
                   prefill_chunk: int = 1):
        if enc_len == 0:
            enc_len = default_enc_len(self.cfg)
        return B.init_cache(self.cfg, batch, max_context, self.dtype,
                            enc_len=enc_len, chunk=prefill_chunk)

    def init_paged_cache(self, n_slots: int, num_blocks: int,
                         block_size: int, enc_len: int = 0):
        """Physically paged serving cache: block pools + per-slot state
        (DESIGN §9)."""
        if enc_len == 0:
            enc_len = default_enc_len(self.cfg)
        return B.init_paged_cache(self.cfg, n_slots, num_blocks, block_size,
                                  self.dtype, enc_len=enc_len)

    def prefill_paged(self, params, tokens, positions, tables, cache,
                      extras: Optional[Dict[str, jnp.ndarray]] = None,
                      last_only: bool = False):
        """Chunked prefill through the paged pools: `tables` is the (B, MB)
        per-request physical block table (DESIGN §9)."""
        return B.forward_cached(params, tokens, positions, cache, self.cfg,
                                decode=False, extras=extras,
                                last_only=last_only, tables=tables)

    def decode_step_paged(self, params, tokens, seq_lens, tables, cache):
        """Paged decode step (DESIGN §9): like `decode_step` but K/V are
        read and written through the per-request block tables."""
        logits, cache = B.forward_cached(
            params, tokens[:, None], seq_lens[:, None], cache, self.cfg,
            decode=True, tables=tables)
        return logits[:, 0], cache

    def prefill(self, params, tokens, positions, cache,
                extras: Optional[Dict[str, jnp.ndarray]] = None,
                last_only: bool = False):
        """Chunked prefill: tokens/positions (B, T), -1 positions = padding.

        last_only=True returns logits for the final position only (B, 1, V)
        — the production serving path."""
        return B.forward_cached(params, tokens, positions, cache, self.cfg,
                                decode=False, extras=extras,
                                last_only=last_only)

    def decode_step(self, params, tokens, seq_lens, cache):
        """tokens: (B,) next input token ids; seq_lens: (B,) their absolute
        positions. Returns (logits (B, V), cache)."""
        logits, cache = B.forward_cached(
            params, tokens[:, None], seq_lens[:, None], cache, self.cfg,
            decode=True)
        return logits[:, 0], cache


def build_model(cfg: ModelConfig, dtype=None) -> Model:
    return Model(cfg, dtype)


def default_enc_len(cfg: ModelConfig) -> int:
    if cfg.family == ArchFamily.ENCDEC:
        return AUDIO_FRAMES
    if cfg.family == ArchFamily.VLM:
        return IMAGE_PATCHES
    return 0


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for the dry-run (no allocation)


def input_specs(cfg: ModelConfig, shape: InputShape,
                dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Abstract inputs for (arch x input-shape), per DESIGN §4.

    train  -> {tokens, (enc_frames|images)}
    prefill-> {tokens, positions, cache, (extras)}
    decode -> {tokens (B,), seq_lens (B,), cache at seq_len context}
    """
    B_, T = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    enc_len = default_enc_len(cfg)
    d = cfg.d_model

    if shape.kind == "train":
        specs: Dict[str, Any] = {"tokens": sds((B_, T), i32)}
        if cfg.family == ArchFamily.ENCDEC:
            specs["enc_frames"] = sds((B_, enc_len, d), dtype)
        if cfg.family == ArchFamily.VLM:
            specs["images"] = sds((B_, enc_len, d), dtype)
        return specs

    chunk = T if shape.kind == "prefill" else 1
    cache = jax.eval_shape(
        lambda: B.init_cache(cfg, B_, T, dtype, enc_len=enc_len, chunk=chunk))

    if shape.kind == "prefill":
        specs = {
            "tokens": sds((B_, T), i32),
            "positions": sds((B_, T), i32),
            "cache": cache,
        }
        if cfg.family == ArchFamily.ENCDEC:
            specs["extras"] = {"enc_frames": sds((B_, enc_len, d), dtype)}
        if cfg.family == ArchFamily.VLM:
            specs["extras"] = {"images": sds((B_, enc_len, d), dtype)}
        return specs

    # decode: ONE new token against a seq_len-deep cache
    return {
        "tokens": sds((B_,), i32),
        "seq_lens": sds((B_,), i32),
        "cache": cache,
    }
