"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block in pure JAX.

Train/prefill use the chunked SSD algorithm (quadratic intra-chunk term +
lax.scan inter-chunk state passing); decode uses the O(1) recurrent step.
The intra-chunk core is the target of kernels/ssd_scan.py.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models.layers import dense_init, rms_norm


def ssm_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = s.num_heads or d_inner // s.head_dim
    return d_inner, nheads, s.head_dim, s.state_dim


def init_mamba2_block(key, cfg: ModelConfig, dtype):
    s = cfg.ssm
    d = cfg.d_model
    d_in, H, P, N = ssm_dims(cfg)
    conv_ch = d_in + 2 * N               # x, B, C pass through the causal conv
    ks = jax.random.split(key, 4)
    d_proj = 2 * d_in + 2 * N + H        # z, x, B, C, dt
    return {
        "in_proj": dense_init(ks[0], (d, d_proj), dtype=dtype),
        "conv_w": dense_init(ks[1], (s.conv_width, conv_ch), scale=0.2, dtype=dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (H,), minval=math.log(1e-3),
                                       maxval=math.log(1e-1))))).astype(jnp.float32),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "norm_w": jnp.zeros((d_in,), dtype),
        "out_proj": dense_init(ks[3], (d_in, d),
                               scale=0.02 / math.sqrt(2 * cfg.num_layers),
                               dtype=dtype),
    }


# ---------------------------------------------------------------------------
# chunked SSD (training / prefill)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, h0=None):
    """Chunked SSD scan.

    x:  (B, T, H, P)   inputs per head
    dt: (B, T, H)      discretization steps (post-softplus, >0)
    A:  (H,)           negative real decay
    Bm: (B, T, N)      input projection (single group)
    Cm: (B, T, N)      output projection
    h0: optional (B, H, P, N) initial state
    Returns y: (B, T, H, P), final state (B, H, P, N).
    """
    Bsz, T, H, P = x.shape
    N = Bm.shape[-1]
    pad = (-T) % chunk
    if pad:  # zero-dt padding is state-neutral (a=1, no input contribution)
        zf = lambda a: jnp.concatenate(
            [a, jnp.zeros(a.shape[:1] + (pad,) + a.shape[2:], a.dtype)], axis=1)
        x, dt, Bm, Cm = zf(x), zf(dt), zf(Bm), zf(Cm)
        T = T + pad
    nc = T // chunk
    f32 = jnp.float32

    xr = x.reshape(Bsz, nc, chunk, H, P).astype(f32)
    dtr = dt.reshape(Bsz, nc, chunk, H).astype(f32)
    Br = Bm.reshape(Bsz, nc, chunk, N).astype(f32)
    Cr = Cm.reshape(Bsz, nc, chunk, N).astype(f32)

    a = dtr * A[None, None, None, :]                      # (B,nc,Q,H) log-decay
    cum_a = jnp.cumsum(a, axis=2)                         # within-chunk cumsum
    seg_end = cum_a[:, :, -1:, :]                         # (B,nc,1,H)

    # intra-chunk: L[i,j] = exp(cum_a_i - cum_a_j) for i >= j
    li = cum_a[:, :, :, None, :]                          # (B,nc,Q,1,H)
    lj = cum_a[:, :, None, :, :]                          # (B,nc,1,Q,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(li - lj), 0.0)
    cb = jnp.einsum("bzin,bzjn->bzij", Cr, Br)            # (B,nc,Q,Q)
    w = cb[..., None] * L                                 # (B,nc,Q,Q,H)
    xdt = xr * dtr[..., None]                             # (B,nc,Q,H,P)
    y_intra = jnp.einsum("bzijh,bzjhp->bzihp", w, xdt)

    # per-chunk state contribution: decay-to-chunk-end applied to each token
    decay_to_end = jnp.exp(seg_end - cum_a)               # (B,nc,Q,H)
    s_chunk = jnp.einsum("bzjn,bzjhp->bzhpn", Br, xdt * decay_to_end[..., None])

    # inter-chunk scan: h_{c} = exp(seg_end_c) h_{c-1} + s_chunk_c
    chunk_decay = jnp.exp(seg_end[:, :, 0, :])            # (B,nc,H)

    def step(h, inputs):
        dec, s = inputs                                   # (B,H), (B,H,P,N)
        h_prev = h
        h = dec[:, :, None, None] * h + s
        return h, h_prev

    init = jnp.zeros((Bsz, H, P, N), f32) if h0 is None else h0.astype(f32)
    hT, h_prevs = jax.lax.scan(
        step, init,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(s_chunk, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                 # (B,nc,H,P,N)

    # inter-chunk output: C_i · (decay-from-chunk-start * h_prev)
    decay_from_start = jnp.exp(cum_a)                     # (B,nc,Q,H)
    y_inter = jnp.einsum("bzin,bzhpn->bzihp", Cr, h_prevs) \
        * decay_from_start[..., None]

    y = (y_intra + y_inter).reshape(Bsz, T, H, P)
    if pad:
        y = y[:, :T - pad]
    return y.astype(x.dtype), hT


def ssd_decode_step(x, dt, A, Bm, Cm, h):
    """One recurrent step. x: (B,1,H,P), dt: (B,1,H), Bm/Cm: (B,1,N),
    h: (B,H,P,N) fp32. Returns (y (B,1,H,P), h')."""
    f32 = jnp.float32
    xd = x[:, 0].astype(f32) * dt[:, 0][..., None]        # (B,H,P)
    a = jnp.exp(dt[:, 0].astype(f32) * A)                 # (B,H)
    h = a[:, :, None, None] * h + jnp.einsum(
        "bn,bhp->bhpn", Bm[:, 0].astype(f32), xd)
    y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(f32), h)
    return y[:, None].astype(x.dtype), h


# ---------------------------------------------------------------------------
# causal depthwise conv (shared by train & decode paths)


def causal_conv(x, w, b, state=None):
    """x: (B, T, Ch), w: (W, Ch) depthwise. state: (B, W-1, Ch) history or None.
    Returns (y, new_state)."""
    W = w.shape[0]
    Bsz, T, Ch = x.shape
    if state is None:
        state = jnp.zeros((Bsz, W - 1, Ch), x.dtype)
    xin = jnp.concatenate([state, x], axis=1)             # (B, W-1+T, Ch)
    y = jnp.zeros((Bsz, T, Ch), jnp.float32)
    for i in range(W):
        y = y + xin[:, i:i + T].astype(jnp.float32) * w[i].astype(jnp.float32)
    y = (y + b.astype(jnp.float32)).astype(x.dtype)
    new_state = xin[:, T:]                                # last W-1 inputs
    return y, new_state


# ---------------------------------------------------------------------------
# full block


def _split_proj(z, cfg: ModelConfig):
    d_in, H, P, N = ssm_dims(cfg)
    zs = jnp.split(z, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1)
    gate, xs, Bm, Cm, dt_raw = zs
    return gate, xs, Bm, Cm, dt_raw


def mamba2_block(p, u, cfg: ModelConfig, *, conv_state=None, ssm_state=None,
                 decode: bool = False):
    """u: (B, T, d). Returns (out, (conv_state, ssm_state))."""
    d_in, H, P, N = ssm_dims(cfg)
    z = u @ p["in_proj"]
    gate, xs, Bm, Cm, dt_raw = _split_proj(z, cfg)
    xbc = jnp.concatenate([xs, Bm, Cm], axis=-1)
    xbc, conv_state = causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc)
    xs, Bm, Cm = jnp.split(xbc, [d_in, d_in + N], axis=-1)
    Bsz, T, _ = xs.shape
    xh = xs.reshape(Bsz, T, H, P)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    if decode:
        y, ssm_state = ssd_decode_step(xh, dt, A, Bm, Cm, ssm_state)
    else:
        chunk = min(cfg.ssm.chunk_size, T)
        y, ssm_state = ssd_chunked(xh, dt, A, Bm, Cm, chunk, h0=ssm_state)
    y = y + xh.astype(jnp.float32).astype(y.dtype) * p["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(Bsz, T, d_in)
    y = rms_norm(y * jax.nn.silu(gate), p["norm_w"], cfg.rms_eps)
    return y @ p["out_proj"], (conv_state, ssm_state)
