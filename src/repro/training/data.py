"""Synthetic-but-learnable data pipeline.

Generates batches from a fixed-seed Markov chain over the vocabulary so a
correct model shows monotonically decreasing loss (the integration tests
assert this), with deterministic sharding across data-parallel ranks.
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np

from repro.config.base import ArchFamily, ModelConfig, TrainConfig


class MarkovData:
    def __init__(self, cfg: ModelConfig, train: TrainConfig, order: int = 1,
                 branching: int = 4):
        self.cfg = cfg
        self.train = train
        rng = np.random.RandomState(train.seed)
        V = cfg.vocab_size
        # sparse transition table: each token has `branching` likely successors
        self.next_tokens = rng.randint(0, V, size=(V, branching))
        self.rng = np.random.RandomState(train.seed + 1)

    def sample_tokens(self, batch: int, seq: int) -> np.ndarray:
        V = self.cfg.vocab_size
        out = np.empty((batch, seq), np.int32)
        cur = self.rng.randint(0, V, size=batch)
        for t in range(seq):
            out[:, t] = cur
            choice = self.rng.randint(0, self.next_tokens.shape[1], size=batch)
            cur = self.next_tokens[cur, choice]
        return out

    def batches(self) -> Iterator[Dict[str, np.ndarray]]:
        t = self.train
        d = self.cfg.d_model
        while True:
            b: Dict[str, np.ndarray] = {
                "tokens": self.sample_tokens(t.global_batch, t.seq_len)}
            if self.cfg.family == ArchFamily.ENCDEC:
                b["enc_frames"] = self.rng.randn(
                    t.global_batch, 64, d).astype(np.float32)
            if self.cfg.family == ArchFamily.VLM:
                b["images"] = self.rng.randn(
                    t.global_batch, 64, d).astype(np.float32)
            yield b
