"""AdamW + cosine schedule with warmup, pure JAX pytrees (no optax)."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config.base import TrainConfig


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def lr_schedule(step, cfg: TrainConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, opt_state, cfg: TrainConfig):
    step = opt_state["step"] + 1
    lr = lr_schedule(step, cfg)
    gn = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-9)) if cfg.grad_clip \
        else 1.0
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + 1e-8) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    params = jax.tree.unflatten(tdef, [n[0] for n in new])
    m = jax.tree.unflatten(tdef, [n[1] for n in new])
    v = jax.tree.unflatten(tdef, [n[2] for n in new])
    return params, {"m": m, "v": v, "step": step}, {"lr": lr, "grad_norm": gn}
