"""Training loop: jit'd AdamW step + host loop with logging/checkpointing."""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig, TrainConfig
from repro.models.model import Model
from repro.training.data import MarkovData
from repro.training.optimizer import adamw_init, adamw_update


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: int = 0


def make_train_step(model: Model, tcfg: TrainConfig) -> Callable:
    """Pure (params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: model.loss_fn(p, batch, remat=tcfg.remat),
            has_aux=True)(params)
        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, tcfg)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def train(model: Model, tcfg: TrainConfig,
          data: Optional[MarkovData] = None,
          log: Optional[Callable[[str], None]] = print,
          checkpoint_path: Optional[str] = None) -> Dict[str, Any]:
    cfg: ModelConfig = model.cfg
    data = data or MarkovData(cfg, tcfg)
    key = jax.random.PRNGKey(tcfg.seed)
    params = model.init(key)
    opt_state = adamw_init(params)
    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0, 1))

    losses: List[float] = []
    it = data.batches()
    t0 = time.perf_counter()
    for step in range(1, tcfg.steps + 1):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if log and (step % tcfg.log_every == 0 or step == 1):
            log(f"step {step:5d} loss {loss:.4f} "
                f"lr {float(metrics['lr']):.2e} "
                f"gnorm {float(metrics['grad_norm']):.2f}")
    wall = time.perf_counter() - t0
    if checkpoint_path:
        from repro.training.checkpoint import save_checkpoint
        save_checkpoint(checkpoint_path, params, opt_state, tcfg.steps)
    return {"losses": losses, "params": params, "opt_state": opt_state,
            "wall_s": wall,
            "tokens_per_s": tcfg.steps * tcfg.global_batch * tcfg.seq_len / wall}
