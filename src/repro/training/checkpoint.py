"""Minimal dependency-free checkpointing: flattened pytree -> .npz."""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree, prefix="") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def _base(path: str) -> str:
    return path if path.endswith(".npz") else path + ".npz"


def save_checkpoint(path: str, params, opt_state, step: int) -> None:
    base = _base(path)
    os.makedirs(os.path.dirname(base) or ".", exist_ok=True)
    flat = _flatten({"params": params, "opt": opt_state})
    np.savez(base, **flat)
    with open(base + ".meta.json", "w") as f:
        json.dump({"step": step, "keys": sorted(flat)}, f)


def load_checkpoint(path: str, params_like, opt_like) -> Tuple[Any, Any, int]:
    base = _base(path)
    data = np.load(base)
    with open(base + ".meta.json") as f:
        meta = json.load(f)

    def rebuild(like, prefix):
        flat_like, tdef = jax.tree.flatten(like)
        keys = _flatten(like, prefix)
        # keys order must match tree.flatten order: rebuild by walking again
        named = list(_named_leaves(like, prefix))
        leaves = [data[name] for name, _ in named]
        return jax.tree.unflatten(tdef, leaves)

    return (rebuild(params_like, "params/"), rebuild(opt_like, "opt/"),
            meta["step"])


def _named_leaves(tree, prefix=""):
    if isinstance(tree, dict):
        for k in sorted(tree):  # jax.tree flattens dicts in sorted-key order
            yield from _named_leaves(tree[k], f"{prefix}{k}/")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _named_leaves(v, f"{prefix}{i}/")
    else:
        yield prefix[:-1], tree
