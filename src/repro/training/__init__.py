from repro.training.optimizer import adamw_init, adamw_update  # noqa: F401
from repro.training.train_loop import TrainState, train  # noqa: F401
