"""Paper Fig 4: SLA attainment vs offered load (qps) — the capacity knee —
for static vs dynamic batching at a 50 ms decode SLA."""
from __future__ import annotations

import time

from benchmarks.paper_models import deployment, llama3_70b
from benchmarks.table2_sla import attainment

QPS_GRID = (2, 4, 8, 12, 16, 24, 32, 48, 64, 96)


def run(csv_out) -> None:
    for policy in ("static", "combined"):
        knee = 0.0
        t0 = time.perf_counter()
        for q in QPS_GRID:
            res = attainment(llama3_70b, 8, 256.6, 61.5, 600, False,
                             policy, q)
            csv_out(f"fig4_{policy}_q{q}",
                    (time.perf_counter() - t0) * 1e6 / len(QPS_GRID),
                    f"attain={res.sla_attainment:.3f} "
                    f"tbt_p95={res.tbt_ms_p95:.1f}ms")
            if res.sla_attainment >= 0.9:
                knee = q
        csv_out(f"fig4_{policy}_capacity", (time.perf_counter() - t0) * 1e6,
                f"capacity={knee}qps")
