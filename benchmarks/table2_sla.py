"""Paper Table II: capacity (max qps meeting the SLA) + throughput under a
50 ms TBT SLA, static vs SLA-constrained dynamic batching. Third row runs
PD fusion (chunked prefill with controller-driven chunk budget)."""
from __future__ import annotations

import time

from benchmarks.paper_models import deployment, llama3_70b, llama_65b
from repro.config.base import ServeConfig
from repro.serving.cost_model import CostModel
from repro.serving.sim import LengthDist, ServingSimulator

ROWS = [
    # label, cfg, chips, mean_in, mean_out, n, chunked, paper_gain_pct
    ("llama-65b", llama_65b, 8, 237.7, 416.2, 800, False, 2.7),
    ("llama3-70b", llama3_70b, 8, 256.6, 61.5, 800, False, 22.4),
    ("llama3-70b-pd", llama3_70b, 8, 256.6, 447.5, 800, True, 25.9),
]

SLA_MS = 50.0


def attainment(cfg_fn, chips, mi, mo, n, chunked, policy, qps, seed=0):
    cfg = cfg_fn()
    cost = CostModel(cfg, deployment(chips, overhead_ms=15.0))
    lengths = LengthDist(mean_in=mi, mean_out=mo, cv_in=0.3, cv_out=0.5)
    # the PD row runs 4 prefill lanes (DESIGN §6): single-lane fusion
    # serializes prefill behind the head-of-line prompt under load
    serve = ServeConfig(policy=policy, b_max=256, d_sla_ms=SLA_MS,
                        eps_d_ms=3.0, max_new_tokens=int(mo * 6) + 8,
                        chunked_prefill=chunked, chunk_budget_tokens=256,
                        n_prefill_lanes=4 if chunked else 1,
                        prefill_pack="srf")
    sim = ServingSimulator(cfg, serve, cost, lengths, seed=seed)
    sim.add_requests(n, arrival_rate=qps)
    res = sim.run()
    return res


TTFT_BOUND_S = 30.0   # queueing criterion: p90 time-to-first-token


def capacity(cfg_fn, chips, mi, mo, n, chunked, policy,
             grid=(0.5, 1, 2, 3, 4, 5, 6, 8, 10, 12, 16, 24, 32, 48, 64, 96)):
    """Max qps meeting the SLOs (Sarathi-style capacity [21]): >= 90% of
    decode steps within the TBT SLA AND p90 TTFT bounded (otherwise a
    throttling scheduler could 'meet' the TBT SLA by queueing forever)."""
    best_q, best_res = 0.0, None
    fails = 0
    for q in grid:
        res = attainment(cfg_fn, chips, mi, mo, n, chunked, policy, q)
        ok = (res.sla_attainment >= 0.90 and res.finished == n
              and res.ttft_p90_s <= TTFT_BOUND_S)
        if ok:
            best_q, best_res = q, res
            fails = 0
        else:
            fails += 1
            if fails >= 2:
                break
    return best_q, best_res


def run(csv_out) -> None:
    for (label, cfg_fn, chips, mi, mo, n, chunked, paper) in ROWS:
        t0 = time.perf_counter()
        cap_s, res_s = capacity(cfg_fn, chips, mi, mo, n, chunked, "static")
        cap_d, res_d = capacity(cfg_fn, chips, mi, mo, n, chunked, "combined")
        us = (time.perf_counter() - t0) * 1e6
        tp_s = res_s.throughput_tok_s if res_s else 0.0
        tp_d = res_d.throughput_tok_s if res_d else 0.0
        gain = (tp_d / max(tp_s, 1e-9) - 1) * 100
        csv_out(
            f"table2_{label}", us,
            f"cap_static={cap_s}qps cap_dynamic={cap_d}qps "
            f"tput_static={tp_s:.0f} tput_dynamic={tp_d:.0f} "
            f"gain={gain:+.1f}% paper={paper:+.1f}%")
