"""Trace replay + goodput benchmark (DESIGN §15): static-vs-dynamic
batching goodput on a replayed multi-turn trace — the paper's Table I/II
comparison rerun on traced (production-shaped) load — plus the real
reduced engine replaying a small trace end to end.

Goodput counts only the tokens of requests that met BOTH per-request SLOs
(TTFT <= ttft_sla_s, mean TBT <= tbt_sla_ms): a scheduler that posts high
token throughput by starving tail requests scores low. The simulator
section replays the SAME bundled reference trace (deterministic
`reference_trace` — no external download, so CI can run it) through the
static batcher and the paper's combined controller at LLaMA3-70B x8
scale, reporting `goodput_tok_s` / `request_sla_attainment` side by side.
The engine section replays a reduced-scale trace through the real paged
engine and reports the same summary keys the differential harness pins
against the sim.

Writes `BENCH_trace.json`.
"""
from __future__ import annotations

import json
import time

# the paper-scale SLOs the sim section grades against. TTFT: the same
# 30 s queueing bound table2's capacity search uses. TBT: 3x the 50 ms
# step SLA — per-request mean TBT includes the prefill stalls of
# co-admitted prompts, not just the decode step. Under the Fig-3 step
# law (28 ms + 0.225 ms/seq) the static preset admits every burst
# arrival at once, so its decode steps swell AND each admission wave
# stalls all running decoders behind full prefills (median per-request
# TBT ~250 ms); the SLA-constrained controller caps the batch near
# (d_sla - eps - c0)/c1 ~ 84 and queues the burst tail instead, trading
# TTFT slack (which the SLO has) for TBT (which it doesn't).
TTFT_SLA_S = 30.0
TBT_SLA_MS = 150.0


def _paper_trace():
    from repro.serving.workload import reference_trace
    # LLaMA3-70B-shaped lengths on a bursty arrival law: 2 rps quiet /
    # 20 rps burst at 25% duty — burst demand exceeds what the static
    # preset can serve within the TBT SLO, quiet demand does not
    return reference_trace(
        600, seed=0, vocab_size=32_000, base_rate=2.0, burst_rate=20.0,
        period_s=50.0, duty=0.25, n_system_prompts=4, system_len=64,
        user_mean=120.0, out_mean=120.0, length_cv=0.5, p_followup=0.5,
        max_turns=3, turn_gap_s=10.0)


def _sim_mode(policy: str, events) -> dict:
    from benchmarks.paper_models import deployment, llama3_70b
    from repro.config.base import ServeConfig
    from repro.serving.cost_model import CostModel
    from repro.serving.sim import LengthDist, ServingSimulator
    from repro.serving.workload import feed_trace

    cfg = llama3_70b()
    # the paper's own Fig-3 LLaMA3-70B x8 step law (tau = 28ms + 0.225ms*b)
    cost = CostModel(cfg, deployment(8), c0_ms=28.0, c1_ms=0.225)
    mi = sum(e.prompt_len for e in events) / len(events)
    mo = sum(e.l_out for e in events) / len(events)
    serve = ServeConfig(policy=policy, b_max=256, d_sla_ms=50.0,
                        eps_d_ms=3.0, max_new_tokens=int(mo * 8) + 8,
                        ttft_sla_s=TTFT_SLA_S, tbt_sla_ms=TBT_SLA_MS)
    sim = ServingSimulator(cfg, serve, cost,
                           LengthDist(mean_in=mi, mean_out=mo), seed=0)
    feed_trace(sim, events)
    res = sim.run()
    return {
        "throughput_tok_s": res.throughput_tok_s,
        "goodput_tok_s": res.goodput_tok_s,
        "goodput_tokens": int(res.goodput_tokens),
        "sla_requests_met": int(res.sla_requests_met),
        "request_sla_attainment": res.request_sla_attainment,
        "sla_attainment": res.sla_attainment,
        "tbt_ms_mean": res.tbt_ms_mean,
        "ttft_p90_s": res.ttft_p90_s,
        "finished": int(res.finished),
        "rejected": int(res.rejected),
        "duration_s": res.duration_s,
    }


def _engine_replay() -> dict:
    import jax
    import jax.numpy as jnp

    from repro.config.base import ServeConfig
    from repro.config.registry import get_config
    from repro.models.model import build_model
    from repro.serving.engine import Engine
    from repro.serving.workload import reference_trace, trace_prompts

    cfg = get_config("granite-3-8b", "reduced")
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    serve = ServeConfig(policy="memory", b_max=8, max_new_tokens=24,
                        kv_pool_tokens=2048, block_size=16,
                        chunked_prefill=True, chunk_budget_tokens=32,
                        n_prefill_lanes=2, paged_kv=True,
                        batch_buckets=(1, 2, 4, 8),
                        ttft_sla_s=120.0, tbt_sla_ms=10_000.0)
    eng = Engine(model, params, serve, max_context=160,
                 buckets=(1, 2, 4, 8), prefill_chunk=8)
    eng.warmup()
    events = reference_trace(24, seed=3, vocab_size=cfg.vocab_size,
                             system_len=12, user_mean=10.0, out_mean=8.0,
                             p_followup=0.6, max_turns=3)
    t0 = time.perf_counter()
    for toks, lo in trace_prompts(events, cfg.vocab_size, seed=0):
        eng.submit(toks, max_new_tokens=max(1, min(lo, 24)))
    eng.run()
    wall_s = time.perf_counter() - t0
    s = eng.summary()
    return {
        "requests": len(events),
        "multi_turn": sum(1 for e in events if e.parent_id is not None),
        "wall_s": wall_s,
        "throughput_tok_s": s["throughput_tok_s"],
        "goodput_tok_s": s["goodput_tok_s"],
        "goodput_tokens": int(s["goodput_tokens"]),
        "sla_requests_met": int(s["sla_requests_met"]),
        "request_sla_attainment": s["request_sla_attainment"],
        "tbt_ms_mean": s["tbt_ms_mean"],
        "finished": int(s["finished"]),
        "rejected": int(s["rejected"]),
    }


def run_trace_goodput(out_json: str = "BENCH_trace.json",
                      csv_out=None) -> dict:
    events = _paper_trace()
    results: dict = {
        "trace": {
            "requests": len(events),
            "multi_turn": sum(1 for e in events
                              if e.parent_id is not None),
            "mean_prompt_len": sum(e.prompt_len for e in events)
            / len(events),
            "mean_output_len": sum(e.l_out for e in events) / len(events),
            "horizon_s": events[-1].t,
            "ttft_sla_s": TTFT_SLA_S,
            "tbt_sla_ms": TBT_SLA_MS,
        },
    }
    results["sim_static"] = _sim_mode("static", events)
    results["sim_dynamic"] = _sim_mode("combined", events)
    results["goodput_gain_pct"] = (
        results["sim_dynamic"]["goodput_tok_s"]
        / max(results["sim_static"]["goodput_tok_s"], 1e-9) - 1) * 100
    if csv_out:
        for mode in ("sim_static", "sim_dynamic"):
            r = results[mode]
            csv_out(f"trace_{mode}", 0.0,
                    f"goodput={r['goodput_tok_s']:.0f}tok/s "
                    f"tput={r['throughput_tok_s']:.0f}tok/s "
                    f"req_sla={r['request_sla_attainment']:.3f}")

    results["engine_replay"] = _engine_replay()

    with open(out_json, "w") as f:
        json.dump(results, f, indent=2)
    if csv_out:
        e = results["engine_replay"]
        csv_out("trace_engine_replay", e["wall_s"] * 1e6,
                f"finished={e['finished']} "
                f"req_sla={e['request_sla_attainment']:.3f}")
        csv_out("trace_summary", 0.0,
                f"goodput_gain={results['goodput_gain_pct']:+.1f}% "
                f"-> {out_json}")
    return results


def run(csv_out) -> None:
    run_trace_goodput(csv_out=csv_out)
