"""Prefix-caching benchmark (DESIGN §10): the real engine on a
shared-system-prompt multi-turn burst, `prefix_cache` on vs off.

Sharing full prompt blocks turns most of each prompt's prefill into an O(1)
block-table mapping, so TTFT drops (only the suffix is chunk-prefilled) and
a tight pool admits more concurrent requests (deduped physical usage).
Decoded tokens are identical in both modes — the comparison isolates the
allocator. Writes a `BENCH_prefix.json` artifact with TTFT, admitted
capacity, hit rate, and copy bytes per mode, plus an engine-vs-sim hit-rate
comparison on the identical token stream.
"""
from __future__ import annotations

import json
import time

WAVE_S = 60.0   # arrivals within one wave window are submitted as a burst


def _waves(arrivals):
    """Group a sorted TokenArrival stream into burst waves: multi-turn
    re-arrivals (turn_gap_s >> WAVE_S) land in later waves, after their
    parent turn's blocks were committed."""
    out = []
    for t, toks, lo in arrivals:
        k = int(t // WAVE_S)
        while len(out) <= k:
            out.append([])
        out[k].append((t, toks, lo))
    return [w for w in out if w]


def run_prefix_compare(out_json: str = "BENCH_prefix.json",
                       csv_out=None) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.config.base import ServeConfig
    from repro.config.registry import get_config
    from repro.models.model import build_model
    from repro.serving.cost_model import CostModel, PROFILES
    from repro.serving.engine import Engine
    from repro.serving.sim import LengthDist, ServingSimulator
    from repro.serving.workload import feed_tokens, shared_prefix

    cfg = get_config("granite-3-8b", "reduced")
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    arrivals = shared_prefix(rate=2.0, n=32, vocab_size=cfg.vocab_size,
                             n_system_prompts=3, system_len=64,
                             user_len=(4, 12), mean_out=8.0,
                             p_followup=0.7, max_turns=3,
                             turn_gap_s=2 * WAVE_S, seed=0)
    waves = _waves(arrivals)

    def serve_cfg(prefix: bool) -> ServeConfig:
        # pool sized so the no-sharing mode cannot hold b_max full prompts
        # at once — admitted capacity is then an allocator property. Static
        # policy: the scheduling sequence is then deterministic, so the sim
        # twin below replays the identical admission order (hit-rate parity)
        return ServeConfig(policy="static", b_max=12, max_new_tokens=8,
                           kv_pool_tokens=640, chunked_prefill=True,
                           chunk_budget_tokens=32, n_prefill_lanes=4,
                           prefill_pack="fifo", paged_kv=True,
                           prefix_cache=prefix)

    results: dict = {}
    outputs = {}
    for mode, prefix in (("off", False), ("on", True)):
        eng = Engine(model, params, serve_cfg(prefix), max_context=256,
                     buckets=(1, 2, 4, 8), prefill_chunk=16)
        eng.warmup()
        hs = []
        peak = 0
        t0 = time.perf_counter()
        for wave in waves:
            hs.extend(eng.submit(list(toks), max_new_tokens=8)
                      for _, toks, _ in wave)
            while eng.step():
                peak = max(peak, len(eng.active) + len(eng.prefilling))
        wall_s = time.perf_counter() - t0
        s = eng.summary()
        served = [h for h in hs if h.first_token_time >= 0]
        ttft = sum(h.first_token_time - h.arrival_time for h in served) \
            / max(len(served), 1)
        outputs[mode] = [h.output_tokens for h in hs]
        results[mode] = {
            "ttft_s_mean": ttft,
            "admitted_capacity_peak": peak,
            "prefix_hit_rate": s["prefix_hit_rate"],
            "prefix_hit_tokens": int(s["prefix_hit_tokens"]),
            "copy_bytes": int(s["copy_bytes"]),
            "cached_blocks": int(s["cached_blocks"]),
            "cache_evictions": int(s["cache_evictions"]),
            "finished": int(s["finished"]),
            "oom_events": int(s["oom_events"]),
            "preemptions": int(s["preemptions"]),
            "tbt_ms_mean": s["tbt_ms_mean"],
            "wall_s": wall_s,
        }
        if csv_out:
            csv_out(f"prefix_engine_{mode}", wall_s * 1e6,
                    f"ttft_s={ttft:.3f} hit_rate={s['prefix_hit_rate']:.2f} "
                    f"copy_bytes={int(s['copy_bytes'])} peak={peak}")

    # discrete-event twin on the identical token stream: arrivals snapped
    # to wave starts replay the engine's burst structure, and the static
    # policy makes both scheduling sequences deterministic — the hit rates
    # must agree (DESIGN §10)
    sim = ServingSimulator(cfg, serve_cfg(True),
                           CostModel(cfg, PROFILES["a100x8"]),
                           LengthDist(mean_in=72, mean_out=8),
                           seed=0, prefill_chunk=16, max_context=256)
    feed_tokens(sim, [(WAVE_S * (i + 1), toks, 8)
                      for i, wave in enumerate(waves)
                      for _, toks, _ in wave])
    simres = sim.run()
    results["sim_prefix_hit_rate"] = simres.prefix_hit_rate
    results["outputs_identical"] = outputs["off"] == outputs["on"]
    results["ttft_speedup"] = (results["off"]["ttft_s_mean"]
                               / max(results["on"]["ttft_s_mean"], 1e-9))
    with open(out_json, "w") as f:
        json.dump(results, f, indent=2)
    if csv_out:
        csv_out("prefix_summary", 0.0,
                f"speedup={results['ttft_speedup']:.2f}x "
                f"identical={results['outputs_identical']} "
                f"sim_hit={simres.prefix_hit_rate:.2f} -> {out_json}")
    return results


def run(csv_out) -> None:
    run_prefix_compare(csv_out=csv_out)
