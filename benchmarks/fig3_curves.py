"""Paper Fig 3: throughput Phi(b) (concave, increasing) and decode time D(b)
(linear) vs dynamic batch size — from the calibrated cost model, plus a
real-engine mini-curve on a reduced model (CPU)."""
from __future__ import annotations

import time
from typing import List, Tuple

import repro  # noqa: F401  (PYTHONPATH check)
from benchmarks.paper_models import llama3_70b, deployment
from repro.serving.cost_model import CostModel, PROFILES


def model_curve() -> List[Tuple[int, float, float]]:
    """(b, D(b) ms, Phi(b) tok/s) for the paper's LLaMA3-70B deployment."""
    cost = CostModel(llama3_70b(), PROFILES["paper-fig3"],
                     c0_ms=28.0, c1_ms=0.225)
    rows = []
    for b in (8, 16, 32, 64, 100, 128, 192, 230, 256, 320, 384, 448, 512):
        tau = cost.tau_step_ms(b, 500.0)
        rows.append((b, tau, b / (tau / 1e3)))
    return rows


def real_engine_curve(buckets=(1, 2, 4, 8, 16)) -> List[Tuple[int, float, float]]:
    """Measured TBT vs batch on the reduced model (CPU wall clock)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.config.base import ServeConfig
    from repro.config.registry import get_config
    from repro.models.model import build_model
    from repro.serving.engine import Engine

    cfg = get_config("granite-3-8b", "reduced")
    m = build_model(cfg, dtype=jnp.float32)
    params = m.init(jax.random.PRNGKey(0))
    rows = []
    rng = np.random.RandomState(0)
    for b in buckets:
        serve = ServeConfig(policy="static", b_max=b, max_new_tokens=24,
                            kv_pool_tokens=8192)
        eng = Engine(m, params, serve, max_context=128, buckets=(b,),
                     prefill_chunk=16)
        eng.warmup()
        for _ in range(b):
            eng.submit(list(map(int, rng.randint(0, cfg.vocab_size, size=8))),
                       max_new_tokens=24)
        eng.run()
        s = eng.summary()
        rows.append((b, s["tbt_ms_mean"], s["throughput_tok_s"]))
    return rows


def run(csv_out) -> None:
    t0 = time.perf_counter()
    sim = model_curve()
    # concavity / linearity checks become part of the bench output
    taus = [t for _, t, _ in sim]
    phis = [p for _, _, p in sim]
    lin = all(t2 > t1 for t1, t2 in zip(taus, taus[1:]))
    conc = all(p2 > p1 for p1, p2 in zip(phis, phis[1:]))
    us = (time.perf_counter() - t0) * 1e6
    for b, tau, phi in sim:
        csv_out(f"fig3_sim_b{b}", us / len(sim), f"D={tau:.1f}ms Phi={phi:.0f}tok/s")
    csv_out("fig3_laws", us, f"D_linear={lin} Phi_concave_increasing={conc}")

    t0 = time.perf_counter()
    real = real_engine_curve()
    us = (time.perf_counter() - t0) * 1e6
    for b, tbt, tput in real:
        csv_out(f"fig3_real_b{b}", us / len(real),
                f"TBT={tbt:.1f}ms tput={tput:.1f}tok/s")
