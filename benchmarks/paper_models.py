"""The paper's own evaluation models (public dims) + per-row deployments.

Table I/II rows use LLaMA-65B / LLaMA3-70B / PanGu-7B/38B/135B. PanGu dims
are approximated from param counts (public cards don't publish all sizes);
deployments (chips per model) follow standard practice for each size.
"""
from __future__ import annotations

import dataclasses

from repro.config.base import ArchFamily, ModelConfig
from repro.serving.cost_model import HardwareProfile


def llama_65b() -> ModelConfig:
    return ModelConfig(name="llama-65b", family=ArchFamily.DENSE,
                       num_layers=80, d_model=8192, num_heads=64,
                       num_kv_heads=64, d_ff=22016, vocab_size=32000,
                       source="arXiv:2302.13971")


def llama3_70b() -> ModelConfig:
    return ModelConfig(name="llama3-70b", family=ArchFamily.DENSE,
                       num_layers=80, d_model=8192, num_heads=64,
                       num_kv_heads=8, d_ff=28672, vocab_size=128256,
                       source="arXiv:2407.21783")


def pangu_7b() -> ModelConfig:
    return ModelConfig(name="pangu-7b", family=ArchFamily.DENSE,
                       num_layers=32, d_model=4096, num_heads=32,
                       num_kv_heads=32, d_ff=11008, vocab_size=100000,
                       source="arXiv:2104.12369 (approx dims)")


def pangu_38b() -> ModelConfig:
    return ModelConfig(name="pangu-38b", family=ArchFamily.DENSE,
                       num_layers=48, d_model=8192, num_heads=64,
                       num_kv_heads=64, d_ff=22016, vocab_size=100000,
                       source="arXiv:2104.12369 (approx dims)")


def pangu_135b() -> ModelConfig:
    return ModelConfig(name="pangu-135b", family=ArchFamily.DENSE,
                       num_layers=107, d_model=10240, num_heads=80,
                       num_kv_heads=80, d_ff=27648, vocab_size=100000,
                       source="arXiv:2104.12369 (approx dims)")


def deployment(chips: int, overhead_ms: float = 25.0) -> HardwareProfile:
    """Ascend-910B-class card (paper authors are Huawei): ~376 TF fp16,
    ~1.0 TB/s HBM, 64 GB."""
    return HardwareProfile(name=f"910b-x{chips}", chips=chips,
                           flops_per_chip=376e12, hbm_bw_per_chip=1.0e12,
                           hbm_per_chip=64e9, step_overhead_ms=overhead_ms,
                           parallel_eff=0.85)
