"""Burst response (paper Fig 1 / §II-B): non-stationary lambda(t) with
traffic spikes. Shows the controller's batch tracking the load while static
batching either under-uses the pool or preempt-storms through spikes.

The PD-fusion rows sweep `n_prefill_lanes` (DESIGN §6): with one lane a
burst of arrivals serializes prefill behind the head-of-line prompt and the
decode batch starves; with >= 2 lanes the same chunk budget is packed across
concurrent prefills, raising decode-batch occupancy and cutting mean TTFT
while producing the identical output tokens.

The paged rows run the REAL engine on a reduced config in contiguous vs
physically paged KV mode (DESIGN §9), reporting TBT and copy-bytes for
each and writing a `BENCH_paged.json` artifact.
"""
from __future__ import annotations

import json
import time

from benchmarks.paper_models import deployment, llama3_70b
from repro.config.base import ServeConfig
from repro.serving.cost_model import CostModel
from repro.serving.sim import LengthDist, ServingSimulator
from repro.serving.workload import bursty, feed

LANE_SWEEP = (1, 2, 4, 8)


def make_sim(serve: ServeConfig, seed: int = 0,
             prefill_chunk: int = 0) -> ServingSimulator:
    cfg = llama3_70b()
    cost = CostModel(cfg, deployment(8), c0_ms=28.0, c1_ms=0.225)
    lengths = LengthDist(mean_in=191.0, mean_out=200.0, cv_out=0.5)
    sim = ServingSimulator(cfg, serve, cost, lengths, seed=seed,
                           prefill_chunk=prefill_chunk)
    arrivals = bursty(base_rate=2.0, burst_rate=30.0, period_s=60.0,
                      duty=0.25, n=1200, lengths=lengths, seed=seed)
    feed(sim, arrivals)
    return sim


def run_policy(policy: str, b_max: int, seed: int = 0):
    serve = ServeConfig(policy=policy, b_max=b_max, max_new_tokens=1024,
                        kv_pool_tokens=120_000)
    return make_sim(serve, seed).run()


def run_lanes(n_lanes: int, seed: int = 0):
    serve = ServeConfig(policy="memory", b_max=1024, max_new_tokens=1024,
                        kv_pool_tokens=120_000, chunked_prefill=True,
                        chunk_budget_tokens=512, n_prefill_lanes=n_lanes,
                        prefill_pack="srf")
    return make_sim(serve, seed, prefill_chunk=128).run()


def run_paged_compare(out_json: str = "BENCH_paged.json",
                      csv_out=None) -> dict:
    """Real-engine burst, contiguous vs paged KV cache (DESIGN §9).

    Same submissions in both modes; outputs are identical, so the
    comparison isolates the layout: TBT and the copy-bytes the contiguous
    layout spends on lane promotion / finish compaction / eviction."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.config.registry import get_config
    from repro.models.model import build_model
    from repro.serving.engine import Engine

    cfg = get_config("granite-3-8b", "reduced")
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompts = [list(map(int, rng.randint(0, cfg.vocab_size,
                                         size=rng.randint(8, 48))))
               for _ in range(16)]
    results = {}
    outputs = {}
    for mode, paged in (("contiguous", False), ("paged", True)):
        serve = ServeConfig(policy="memory", b_max=8, max_new_tokens=12,
                            kv_pool_tokens=2048, chunked_prefill=True,
                            chunk_budget_tokens=32, n_prefill_lanes=4,
                            prefill_pack="srf", paged_kv=paged)
        eng = Engine(model, params, serve, max_context=128,
                     buckets=(1, 2, 4, 8), prefill_chunk=16)
        eng.warmup()
        hs = [eng.submit(p, max_new_tokens=12) for p in prompts]
        t0 = time.perf_counter()
        eng.run(max_steps=5000)
        wall_s = time.perf_counter() - t0
        s = eng.summary()
        outputs[mode] = [h.output_tokens for h in hs]
        results[mode] = {
            "tbt_ms_mean": s["tbt_ms_mean"],
            "throughput_tok_s": s["throughput_tok_s"],
            "copy_rows": int(s["copy_rows"]),
            "copy_bytes": int(s["copy_bytes"]),
            "finished": int(s["finished"]),
            "preemptions": int(s["preemptions"]),
            "wall_s": wall_s,
        }
        if csv_out:
            csv_out(f"burst_engine_{mode}", wall_s * 1e6,
                    f"tbt_ms={s['tbt_ms_mean']:.2f} "
                    f"copy_bytes={int(s['copy_bytes'])} "
                    f"finished={int(s['finished'])}")
    results["outputs_identical"] = outputs["contiguous"] == outputs["paged"]
    results["copy_bytes_saved"] = (results["contiguous"]["copy_bytes"]
                                   - results["paged"]["copy_bytes"])
    with open(out_json, "w") as f:
        json.dump(results, f, indent=2)
    if csv_out:
        csv_out("burst_paged_copy_bytes_saved", 0.0,
                f"saved={results['copy_bytes_saved']} "
                f"identical={results['outputs_identical']} -> {out_json}")
    return results


def run(csv_out) -> None:
    for policy, b_max in (("static", 256), ("memory", 1024)):
        t0 = time.perf_counter()
        res = run_policy(policy, b_max)
        us = (time.perf_counter() - t0) * 1e6
        bt = res.batch_trace
        peak = max(bt) if bt else 0
        csv_out(f"burst_{policy}", us,
                f"tput={res.throughput_tok_s:.0f}tok/s mean_batch={res.mean_batch:.0f} "
                f"peak_batch={peak} preempt={res.preemptions} "
                f"oom={res.oom_events} ttft_p90={res.ttft_p90_s:.1f}s")
    # PD-fusion lane sweep (DESIGN §6)
    for n_lanes in LANE_SWEEP:
        t0 = time.perf_counter()
        res = run_lanes(n_lanes)
        us = (time.perf_counter() - t0) * 1e6
        csv_out(f"burst_fused_lanes{n_lanes}", us,
                f"tput={res.throughput_tok_s:.0f}tok/s "
                f"mean_batch={res.mean_batch:.1f} "
                f"ttft_mean={res.ttft_mean_s:.2f}s "
                f"ttft_queue={res.ttft_queue_s_mean:.2f}s "
                f"ttft_prefill={res.ttft_prefill_s_mean:.2f}s "
                f"lane_occ={res.prefill_lane_occupancy:.2f} "
                f"tokens={res.total_tokens}")
    # real-engine paged-vs-contiguous comparison (DESIGN §9)
    run_paged_compare(csv_out=csv_out)
