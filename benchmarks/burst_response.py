"""Burst response (paper Fig 1 / §II-B): non-stationary lambda(t) with
traffic spikes. Shows the controller's batch tracking the load while static
batching either under-uses the pool or preempt-storms through spikes."""
from __future__ import annotations

import time

from benchmarks.paper_models import deployment, llama3_70b
from repro.config.base import ServeConfig
from repro.serving.cost_model import CostModel
from repro.serving.sim import LengthDist, ServingSimulator
from repro.serving.workload import bursty, feed


def run_policy(policy: str, b_max: int, seed: int = 0):
    cfg = llama3_70b()
    cost = CostModel(cfg, deployment(8), c0_ms=28.0, c1_ms=0.225)
    lengths = LengthDist(mean_in=191.0, mean_out=200.0, cv_out=0.5)
    serve = ServeConfig(policy=policy, b_max=b_max, max_new_tokens=1024,
                        kv_pool_tokens=120_000)
    sim = ServingSimulator(cfg, serve, cost, lengths, seed=seed)
    arrivals = bursty(base_rate=2.0, burst_rate=30.0, period_s=60.0,
                      duty=0.25, n=1200, lengths=lengths, seed=seed)
    feed(sim, arrivals)
    return sim.run()


def run(csv_out) -> None:
    for policy, b_max in (("static", 256), ("memory", 1024)):
        t0 = time.perf_counter()
        res = run_policy(policy, b_max)
        us = (time.perf_counter() - t0) * 1e6
        bt = res.batch_trace
        peak = max(bt) if bt else 0
        csv_out(f"burst_{policy}", us,
                f"tput={res.throughput:.0f}tok/s mean_batch={res.mean_batch:.0f} "
                f"peak_batch={peak} preempt={res.preemptions} "
                f"oom={res.oom_events} ttft_p90={res.ttft_p90_s:.1f}s")
