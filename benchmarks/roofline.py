"""Roofline terms per (arch x shape x mesh) from the dry-run artifacts.

TPU v5e constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
Conventions (validated against known decode/train FLOP counts, see
EXPERIMENTS §Roofline): XLA cost_analysis 'flops' and 'bytes accessed' are
per-partition (post-SPMD); collective operand sizes parsed from the HLO are
per-device shard bytes. 'flops' counts MACs for dot ops -> x2 for FLOPs.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional

from repro.config.base import INPUT_SHAPES
from repro.config.registry import get_config

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
ICI_BW = 50e9              # B/s / link (1 link conservative)

DRYRUN_PATH = os.environ.get("REPRO_DRYRUN_JSONL", "results/dryrun.jsonl")


def load(path: str = DRYRUN_PATH) -> List[Dict]:
    if not os.path.exists(path):
        return []
    recs = {}
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            recs[(r["arch"], r["shape"], r["mesh"])] = r  # last wins
    return list(recs.values())


def model_flops(arch: str, shape_name: str) -> float:
    """6*N_active*D train / 2*N_active*D prefill / 2*N_active*B decode
    (global)."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch


def analyse(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    flops_dev = 2.0 * rec.get("flops", 0.0)          # MACs -> FLOPs
    bytes_dev = rec.get("bytes_accessed", 0.0)
    coll = rec.get("collectives", {})
    coll_dev = float(sum(v for k, v in coll.items() if k != "count"))

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    useful = mf / (flops_dev * chips) if flops_dev else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dom,
        "model_flops": mf, "useful_frac": useful,
        "collective_bytes_dev": coll_dev,
    }


def run(csv_out) -> None:
    t0 = time.perf_counter()
    rows = [a for a in (analyse(r) for r in load()) if a]
    us = (time.perf_counter() - t0) * 1e6
    if not rows:
        csv_out("roofline", us, "no dryrun artifacts (run launch/dryrun.py)")
        return
    for a in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        csv_out(
            f"roofline_{a['arch']}_{a['shape']}_{a['mesh']}", us / len(rows),
            f"compute={a['t_compute_s']*1e3:.2f}ms "
            f"memory={a['t_memory_s']*1e3:.2f}ms "
            f"collective={a['t_collective_s']*1e3:.2f}ms "
            f"dom={a['dominant']} useful={a['useful_frac']*100:.0f}%")


def markdown_table(path: str = DRYRUN_PATH) -> str:
    rows = [a for a in (analyse(r) for r in load(path)) if a]
    out = ["| arch | shape | mesh | compute (ms) | memory (ms) | "
           "collective (ms) | dominant | useful FLOP frac |",
           "|---|---|---|---|---|---|---|---|"]
    for a in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        out.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} "
            f"| {a['t_compute_s']*1e3:.2f} | {a['t_memory_s']*1e3:.2f} "
            f"| {a['t_collective_s']*1e3:.2f} | {a['dominant']} "
            f"| {a['useful_frac']*100:.0f}% |")
    return "\n".join(out)
