# One function per paper table. Print ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import argparse
import sys


def csv_out(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


BENCHES = ("fig3", "table1", "table2", "fig4", "ablation", "burst",
           "prefix", "swap", "tp", "async", "trace", "roofline")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=BENCHES, default=None)
    args = ap.parse_args()

    print("name,us_per_call,derived")
    todo = [args.only] if args.only else list(BENCHES)
    for name in todo:
        try:
            if name == "fig3":
                from benchmarks.fig3_curves import run
            elif name == "table1":
                from benchmarks.table1_throughput import run
            elif name == "table2":
                from benchmarks.table2_sla import run
            elif name == "fig4":
                from benchmarks.fig4_capacity import run
            elif name == "ablation":
                from benchmarks.ablation_eps import run
            elif name == "burst":
                from benchmarks.burst_response import run
            elif name == "prefix":
                from benchmarks.prefix_caching import run
            elif name == "swap":
                from benchmarks.kv_swap import run
            elif name == "tp":
                from benchmarks.tp_serving import run
            elif name == "async":
                from benchmarks.async_overlap import run
            elif name == "trace":
                from benchmarks.trace_replay import run
            else:
                from benchmarks.roofline import run
            run(csv_out)
        except Exception as e:  # keep the suite going; report the failure
            csv_out(f"{name}_ERROR", 0.0, repr(e))
            import traceback
            traceback.print_exc(file=sys.stderr)


if __name__ == "__main__":
    main()
