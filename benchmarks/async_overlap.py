"""Async dispatch-ahead pipeline benchmark (DESIGN §14): the real engine
on a burst workload, synchronous loop (overlap_depth=0) vs dispatch-ahead
(overlap_depth=1), plus the simulator at production scale.

The engine section measures what the pipeline actually moves: the
host-vs-device interval split (`step_host_s_mean` / `step_device_s_mean`)
and mean TBT. Under overlap the host runs interval N+1's admission, lane
packing and block-table edits while interval N's step is still on device,
so the TBT fence absorbs host work the synchronous loop would serialize.
Decoded tokens are bitwise-identical in both modes — the acceptance
criterion of the refactor — and the benchmark asserts it.

The simulator section prices the same overlap on the paper's full-size
deployment with the cost model's host_overhead_ms share: each interval
costs max(host, device) instead of host + device, which is the paper's
step-overhead term partially leaving the critical path.

Writes `BENCH_async.json`.
"""
from __future__ import annotations

import json
import time


def run_async_compare(out_json: str = "BENCH_async.json",
                      csv_out=None) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.config.base import ServeConfig
    from repro.config.registry import get_config
    from repro.models.model import build_model
    from repro.serving.cost_model import CostModel, PROFILES
    from repro.serving.engine import Engine
    from repro.serving.sim import LengthDist, ServingSimulator

    cfg = get_config("granite-3-8b", "reduced")
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    # burst workload: three waves of mixed-length prompts arriving at
    # once — admission + packing + table edits every interval, the host
    # work the pipeline is supposed to hide
    waves = [[list(map(int, rng.randint(0, cfg.vocab_size,
                                        size=int(rng.randint(8, 56)))))
              for _ in range(6)] for _ in range(3)]

    def run_mode(depth: int):
        serve = ServeConfig(policy="memory", b_max=8, max_new_tokens=24,
                            kv_pool_tokens=2048, block_size=16,
                            chunked_prefill=True, chunk_budget_tokens=32,
                            n_prefill_lanes=2, paged_kv=True,
                            batch_buckets=(1, 2, 4, 8),
                            overlap_depth=depth)
        eng = Engine(model, params, serve, max_context=160,
                     buckets=(1, 2, 4, 8), prefill_chunk=8)
        eng.warmup()
        hs = []
        t0 = time.perf_counter()
        for wave in waves:
            hs += [eng.submit(p, max_new_tokens=24) for p in wave]
            eng.run()
        wall_s = time.perf_counter() - t0
        s = eng.summary()
        return {
            "overlap_depth": depth,
            "wall_s": wall_s,
            "tbt_ms_mean": s["tbt_ms_mean"],
            "tbt_ms_p95": s["tbt_ms_p95"],
            "step_host_s_mean": s["step_host_s_mean"],
            "step_device_s_mean": s["step_device_s_mean"],
            "throughput_tok_s": s["throughput_tok_s"],
            "decode_steps": int(s["decode_steps"]),
            "finished": int(s["finished"]),
        }, [h.output_tokens for h in hs]

    results: dict = {}
    results["engine_sync"], out_sync = run_mode(0)
    results["engine_overlap"], out_async = run_mode(1)
    results["outputs_identical"] = out_sync == out_async
    assert results["outputs_identical"], \
        "overlap_depth must not change decoded tokens"
    # sync TBT carries host+device serially; overlap TBT is the marginal
    # fence wait after the host pass already ran under the in-flight step
    results["tbt_ms_saved_mean"] = (
        results["engine_sync"]["tbt_ms_mean"]
        - results["engine_overlap"]["tbt_ms_mean"])
    results["engine_wall_speedup"] = (
        results["engine_sync"]["wall_s"]
        / max(results["engine_overlap"]["wall_s"], 1e-9))
    if csv_out:
        for mode in ("engine_sync", "engine_overlap"):
            r = results[mode]
            csv_out(f"async_{mode}", r["wall_s"] * 1e6,
                    f"tbt_ms={r['tbt_ms_mean']:.2f} "
                    f"host_s={r['step_host_s_mean'] * 1e3:.2f}ms "
                    f"dev_s={r['step_device_s_mean'] * 1e3:.2f}ms")

    # simulator at paper scale: host_overhead_ms leaves the critical path
    full = get_config("granite-3-8b")
    cost = CostModel(full, PROFILES["a100x8"])

    def sim_mode(depth: int):
        serve = ServeConfig(policy="memory", b_max=64, max_new_tokens=256,
                            kv_pool_tokens=24_000, block_size=16,
                            overlap_depth=depth, paged_kv=True)
        sim = ServingSimulator(full, serve, cost,
                               LengthDist(mean_in=512, mean_out=224),
                               seed=1)
        sim.add_requests(128, arrival_rate=12.0)
        res = sim.run()
        return {"throughput_tok_s": res.throughput_tok_s,
                "duration_s": res.duration_s,
                "tbt_ms_mean": res.tbt_ms_mean,
                "step_host_s_mean": res.step_host_s_mean,
                "step_device_s_mean": res.step_device_s_mean,
                "finished": res.finished}

    results["sim_sync"] = sim_mode(0)
    results["sim_overlap"] = sim_mode(1)
    results["sim_speedup"] = (results["sim_overlap"]["throughput_tok_s"]
                              / max(results["sim_sync"]["throughput_tok_s"],
                                    1e-9))

    with open(out_json, "w") as f:
        json.dump(results, f, indent=2)
    if csv_out:
        csv_out("async_summary", 0.0,
                f"identical={results['outputs_identical']} "
                f"sim_speedup={results['sim_speedup']:.3f}x "
                f"-> {out_json}")
    return results


def run(csv_out) -> None:
    run_async_compare(csv_out=csv_out)
