"""Two-tier KV swap benchmark (DESIGN §11): the real engine on a bursty
long-context workload under a tight HBM pool, preemption relieved by
host-offload swap vs recompute vs no pressure at all.

The capacity headline is `admitted_peak_tokens`: the peak number of KV
tokens held live for admitted requests across BOTH tiers (device physical
usage + host swap ledger). Recompute caps it at the HBM pool — a victim's
KV is destroyed and rebuilt from scratch — while the swap tier retains the
victim's KV in host RAM, so the two-tier engine sustains strictly more
admitted KV than the same HBM pool alone (the UELLM multi-tier capacity
argument). Decoded tokens are bitwise-identical in all three modes.

A simulator section runs the cost-model crossover ("auto") on the
full-size config, where PCIe round trips genuinely undercut re-prefill
FLOPs, and compares throughput against recompute-only.

Writes `BENCH_swap.json`.
"""
from __future__ import annotations

import json
import time


def run_swap_compare(out_json: str = "BENCH_swap.json", csv_out=None) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.config.base import ServeConfig
    from repro.config.registry import get_config
    from repro.models.model import build_model
    from repro.serving.cost_model import CostModel, PROFILES
    from repro.serving.engine import Engine
    from repro.serving.sim import LengthDist, ServingSimulator

    cfg = get_config("granite-3-8b", "reduced")
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    # bursty long-context: three waves of four long prompts, outputs long
    # enough that the batch outgrows the tight pool mid-decode
    waves = [[list(map(int, rng.randint(0, cfg.vocab_size,
                                        size=int(rng.randint(72, 104)))))
              for _ in range(4)] for _ in range(3)]

    def serve_cfg(pool_tokens, swap_blocks, preempt):
        return ServeConfig(policy="static", b_max=6, max_new_tokens=48,
                           kv_pool_tokens=pool_tokens, block_size=16,
                           chunked_prefill=True, chunk_budget_tokens=16,
                           n_prefill_lanes=2, paged_kv=True,
                           swap_space_blocks=swap_blocks, preempt=preempt)

    def run_mode(pool_tokens, swap_blocks, preempt):
        eng = Engine(model, params,
                     serve_cfg(pool_tokens, swap_blocks, preempt),
                     max_context=160, buckets=(1, 2, 4), prefill_chunk=8)
        eng.warmup()
        hs = []
        peak_tokens = 0
        peak_reqs = 0
        t0 = time.perf_counter()
        for wave in waves:
            hs += [eng.submit(p, max_new_tokens=48) for p in wave]
            while eng.step():
                live_tokens = eng.blocks.physical_used_tokens \
                    + eng.blocks.swapped_tokens
                peak_tokens = max(peak_tokens, live_tokens)
                peak_reqs = max(peak_reqs, len(eng.active)
                                + len(eng.prefilling) + len(eng.swapped))
        wall_s = time.perf_counter() - t0
        s = eng.summary()
        metrics = {
            "admitted_peak_tokens": peak_tokens,
            "admitted_peak_requests": peak_reqs,
            "hbm_pool_tokens": pool_tokens,
            "tbt_ms_mean": s["tbt_ms_mean"],
            "mean_batch": s["mean_batch"],
            "preemptions": int(s["preemptions"]),
            "swap_outs": int(s["swap_outs"]),
            "swap_ins": int(s["swap_ins"]),
            "swap_out_bytes": int(s["swap_out_bytes"]),
            "swap_in_bytes": int(s["swap_in_bytes"]),
            "swapped_peak": int(s["swapped_peak"]),
            "swap_latency_s_mean": s["swap_latency_s_mean"],
            "finished": int(s["finished"]),
            "oom_events": int(s["oom_events"]),
            "wall_s": wall_s,
        }
        return metrics, [h.output_tokens for h in hs]

    results: dict = {}
    outputs = {}
    tight = 320     # 20 blocks: holds ~3 grown long-context requests
    for mode, (pool, swap, preempt) in (
            ("recompute", (tight, 0, "recompute")),
            ("swap", (tight, 64, "swap")),
            ("nopreempt", (8192, 0, "recompute"))):
        results[mode], outputs[mode] = run_mode(pool, swap, preempt)
        if csv_out:
            r = results[mode]
            csv_out(f"swap_engine_{mode}", r["wall_s"] * 1e6,
                    f"peak_tokens={r['admitted_peak_tokens']} "
                    f"tbt_ms={r['tbt_ms_mean']:.2f} "
                    f"preempt={r['preemptions']} swaps={r['swap_outs']}")

    results["outputs_identical"] = (outputs["recompute"] == outputs["swap"]
                                    == outputs["nopreempt"])
    results["capacity_gain_tokens"] = (
        results["swap"]["admitted_peak_tokens"]
        - results["recompute"]["admitted_peak_tokens"])

    # cost-model crossover at production scale: on the full-size config the
    # PCIe round trip undercuts re-prefill FLOPs, so "auto" swaps instead
    # of recomputing and wins back the re-prefill work
    full = get_config("granite-3-8b")
    cost = CostModel(full, PROFILES["a100x8"])
    results["crossover_example"] = {
        "blocks": 128,
        "pcie_roundtrip_ms": 2e3 * cost.pcie_s(128, 16),
        "reprefill_ms": 1e3 * cost.reprefill_s(128 * 16),
        "auto_picks_swap": cost.swap_beats_recompute(128, 16, 128 * 16),
    }

    def sim_mode(preempt, swap_blocks):
        serve = ServeConfig(policy="static", b_max=48, max_new_tokens=512,
                            kv_pool_tokens=20_000, block_size=16,
                            swap_space_blocks=swap_blocks, preempt=preempt,
                            paged_kv=True)
        sim = ServingSimulator(full, serve, cost,
                               LengthDist(mean_in=512, mean_out=384,
                                          cv_out=1.0), seed=1)
        sim.add_requests(96)
        res = sim.run()
        return {"throughput_tok_s": res.throughput_tok_s,
                "tbt_ms_mean": res.tbt_ms_mean,
                "preemptions": res.preemptions,
                "swap_outs": res.swap_outs,
                "swap_ins": res.swap_ins,
                "finished": res.finished}

    results["sim_auto"] = sim_mode("auto", 2048)
    results["sim_recompute"] = sim_mode("recompute", 0)

    with open(out_json, "w") as f:
        json.dump(results, f, indent=2)
    if csv_out:
        csv_out("swap_summary", 0.0,
                f"capacity_gain={results['capacity_gain_tokens']}tok "
                f"identical={results['outputs_identical']} "
                f"auto_swaps={results['sim_auto']['swap_outs']} "
                f"-> {out_json}")
    return results


def run(csv_out) -> None:
    run_swap_compare(csv_out=csv_out)
