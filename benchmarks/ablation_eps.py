"""Ablation: the chance-constraint budget eps_M (paper eq. 2/11).

Sweeps eps_M on a memory-tight deployment (LLaMA-65B MHA, variable output
lengths). Small eps_M = conservative batches, fewer preemptions; large
eps_M = aggressive batches, preemption storms. The sweet spot demonstrates
why the paper treats memory as a *soft* probabilistic constraint."""
from __future__ import annotations

import time

from benchmarks.paper_models import deployment, llama_65b
from repro.config.base import ServeConfig
from repro.serving.cost_model import CostModel
from repro.serving.sim import LengthDist, ServingSimulator

EPS_GRID = (0.5, 0.2, 0.05, 0.01, 0.001)


def run(csv_out) -> None:
    cfg = llama_65b()
    cost = CostModel(cfg, deployment(8), c0_ms=28.0, c1_ms=0.4)
    # pool sized so the CLT margin is the binding constraint:
    # b*(eps=0.5) ~ 145 vs b*(eps=0.001) ~ 131 at mu=413, sigma1=172
    for eps in EPS_GRID:
        t0 = time.perf_counter()
        serve = ServeConfig(policy="memory", b_max=1024, eps_m=eps,
                            max_new_tokens=1024, kv_pool_tokens=60_000)
        sim = ServingSimulator(
            cfg, serve, cost,
            LengthDist(mean_in=68.4, mean_out=344.5, cv_out=0.5), seed=0)
        sim.add_requests(600)
        res = sim.run()
        us = (time.perf_counter() - t0) * 1e6
        csv_out(f"ablation_epsM_{eps}", us,
                f"tput={res.throughput_tok_s:.0f}tok/s "
                f"mean_batch={res.mean_batch:.0f} "
                f"preempt={res.preemptions} oom={res.oom_events}")
