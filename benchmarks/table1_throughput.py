"""Paper Table I: static vs dynamic batching throughput, infinite backlog.

Six rows (LLaMA-65B, LLaMA3-70B x2 prompt sets, PanGu-7/38/135B). Static
baseline = vLLM-style fixed preset (max_num_seqs=256, the vLLM default);
dynamic = Algorithm 1 with B_max = 4096. Deployments: chips sized to the
model (7B on 1 card; 38/65/70B on 8; 135B on 16), 64 GB Ascend-910B-class
cards, gpu_memory_utilization=0.9 (vLLM default).
"""
from __future__ import annotations

import time

from benchmarks.paper_models import (deployment, llama3_70b, llama_65b,
                                     pangu_135b, pangu_38b, pangu_7b)
from repro.config.base import ServeConfig
from repro.serving.cost_model import CostModel
from repro.serving.sim import LengthDist, ServingSimulator

ROWS = [
    # (label, cfg, chips, mean_in, mean_out, n_req, fixed, paper_gain, fig3)
    # LLaMA rows use the step law CALIBRATED FROM THE PAPER'S OWN Fig 3
    # (same authors' LLaMA3-70B deployment: tau = 28ms + 0.225ms*b);
    # PanGu rows use the roofline deployment law (910B-class cards).
    ("llama-65b", llama_65b, 8, 68.4, 344.5, 1319, False, 8.2, True),
    ("llama3-70b-a", llama3_70b, 8, 68.4, 454.4, 1319, False, 6.5, True),
    ("llama3-70b-b", llama3_70b, 8, 191.0, 381.9, 3000, False, 12.2, True),
    ("pangu-7b", pangu_7b, 2, 128, 128, 1000, True, 28.2, False),
    ("pangu-38b", pangu_38b, 8, 128, 128, 1000, True, 26.0, False),
    ("pangu-135b", pangu_135b, 16, 128, 128, 1000, True, 8.0, False),
]

STATIC_PRESET = 256      # vLLM default max_num_seqs
DYNAMIC_BMAX = 1024      # operator hard bound for Algorithm 1


def run_row(cfg_fn, chips, mean_in, mean_out, n_req, fixed, policy, b_max,
            seed=0, fig3_law=False, n_lanes=0):
    cfg = cfg_fn()
    if fig3_law:
        cost = CostModel(cfg, deployment(chips), c0_ms=28.0, c1_ms=0.225)
    else:
        cost = CostModel(cfg, deployment(chips))
    lengths = LengthDist(mean_in=mean_in, mean_out=mean_out, fixed=fixed,
                         cv_in=0.4, cv_out=0.6)
    # n_lanes > 0 switches the row to PD fusion with that many prefill
    # lanes (DESIGN §6)
    serve = ServeConfig(policy=policy, b_max=b_max,
                        max_new_tokens=int(mean_out * 6) + 8,
                        chunked_prefill=n_lanes > 0,
                        n_prefill_lanes=max(n_lanes, 1),
                        prefill_pack="srf")
    sim = ServingSimulator(cfg, serve, cost, lengths, seed=seed)
    sim.add_requests(n_req)   # infinite backlog: all at t=0 (paper setup)
    return sim.run()


PD_LANE_SWEEP = (1, 2, 4)    # PD-fusion lane counts swept on the Fig-3 row


def run(csv_out) -> None:
    for (label, cfg_fn, chips, mi, mo, n, fixed, paper, fig3) in ROWS:
        t0 = time.perf_counter()
        st = run_row(cfg_fn, chips, mi, mo, n, fixed, "static", STATIC_PRESET,
                     fig3_law=fig3)
        dy = run_row(cfg_fn, chips, mi, mo, n, fixed, "memory", DYNAMIC_BMAX,
                     fig3_law=fig3)
        us = (time.perf_counter() - t0) * 1e6
        gain = (dy.throughput_tok_s / max(st.throughput_tok_s, 1e-9) - 1) * 100
        csv_out(
            f"table1_{label}", us,
            f"static={st.throughput_tok_s:.0f}tok/s dynamic={dy.throughput_tok_s:.0f}tok/s "
            f"gain={gain:+.1f}% paper={paper:+.1f}% "
            f"b_static={st.mean_batch:.0f} b_dyn={dy.mean_batch:.0f} "
            f"preempt={st.preemptions}/{dy.preemptions}")
    # PD-fusion lane sweep (DESIGN §6) on the paper's Fig-3 deployment row
    (label, cfg_fn, chips, mi, mo, n, fixed, _, fig3) = ROWS[2]
    for n_lanes in PD_LANE_SWEEP:
        t0 = time.perf_counter()
        fu = run_row(cfg_fn, chips, mi, mo, n, fixed, "memory", DYNAMIC_BMAX,
                     fig3_law=fig3, n_lanes=n_lanes)
        us = (time.perf_counter() - t0) * 1e6
        csv_out(
            f"table1_{label}_fused_lanes{n_lanes}", us,
            f"tput={fu.throughput_tok_s:.0f}tok/s b={fu.mean_batch:.0f} "
            f"ttft_mean={fu.ttft_mean_s:.2f}s "
            f"lane_occ={fu.prefill_lane_occupancy:.2f} "
            f"preempt={fu.preemptions}")
