"""Mesh-sharded (tensor-parallel) serving benchmark (DESIGN §12).

Runs the real paged engine on CPU test meshes with a FIXED per-chip KV
pool while the model axis grows (m = 1, 2, 4): params shard per the §5
name rules, the paged K/V pools shard over "model" on kv-heads, and the
chip-aware MemoryModel scales Alg-1's token capacity with the shard
count. The capacity headline is `admitted_peak_tokens` — the peak KV
tokens held live for admitted requests — which scales with the model
axis at constant per-chip HBM, while decoded tokens stay bitwise
identical to the single-device engine.

Each mesh size runs in a child process (XLA's forced host device count is
fixed at first jax init, so meshes cannot be grown inside one process).

Writes `BENCH_tp.json`.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
MODEL_AXES = (1, 2, 4)
PER_CHIP_POOL_TOKENS = 192     # 12 blocks/chip: tight for the burst below

_CHILD = r"""
import json, sys, time
import jax, jax.numpy as jnp
import numpy as np
from repro.config.base import ServeConfig
from repro.config.registry import get_config
from repro.models.model import build_model
from repro.serving.engine import Engine

m, per_chip_pool = int(sys.argv[1]), int(sys.argv[2])
cfg = get_config("granite-3-8b", "reduced")
model = build_model(cfg, dtype=jnp.float32)
params = model.init(jax.random.PRNGKey(0))

serve = ServeConfig(policy="memory", b_max=8, max_new_tokens=24,
                    kv_pool_tokens=per_chip_pool, block_size=16,
                    chunked_prefill=True, chunk_budget_tokens=32,
                    n_prefill_lanes=2, paged_kv=True,
                    mesh_shape=(1, m) if m > 1 else ())
eng = Engine(model, params, serve, max_context=96, buckets=(1, 2, 4, 8),
             prefill_chunk=16)
eng.warmup()

rng = np.random.RandomState(7)
prompts = [list(map(int, rng.randint(0, cfg.vocab_size,
                                     size=int(rng.randint(28, 44)))))
           for _ in range(10)]
hs = [eng.submit(p, max_new_tokens=24, arrival_time=0.0) for p in prompts]
peak_tokens = peak_reqs = 0
t0 = time.perf_counter()
while eng.step():
    peak_tokens = max(peak_tokens, eng.blocks.physical_used_tokens)
    peak_reqs = max(peak_reqs, len(eng.active) + len(eng.prefilling))
wall_s = time.perf_counter() - t0
s = eng.summary()
print("RESULT" + json.dumps({
    "model_axis": m,
    "model_shards": int(s["model_shards"]),
    "per_chip_pool_tokens": per_chip_pool,
    "pool_tokens_capacity": int(s["pool_tokens"]),
    "admitted_peak_tokens": peak_tokens,
    "admitted_peak_requests": peak_reqs,
    "mean_batch": s["mean_batch"],
    "tbt_ms_mean": s["tbt_ms_mean"],
    "preemptions": int(s["preemptions"]),
    "oom_events": int(s["oom_events"]),
    "finished": int(s["finished"]),
    "copy_rows": int(s["copy_rows"]),
    "wall_s": wall_s,
    "outputs": [h.output_tokens for h in hs],
}))
"""


def _run_child(model_axis: int) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{max(model_axis, 1)}")
    proc = subprocess.run([sys.executable, "-c", _CHILD, str(model_axis),
                           str(PER_CHIP_POOL_TOKENS)],
                          env=env, capture_output=True, text=True,
                          timeout=900)
    if proc.returncode != 0:
        raise RuntimeError(f"tp child (m={model_axis}) failed:\n"
                           f"{proc.stderr[-2000:]}")
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


def run_tp_scaling(out_json: str = "BENCH_tp.json", csv_out=None) -> dict:
    results: dict = {"per_chip_pool_tokens": PER_CHIP_POOL_TOKENS,
                     "meshes": []}
    outputs = {}
    for m in MODEL_AXES:
        r = _run_child(m)
        outputs[m] = r.pop("outputs")
        results["meshes"].append(r)
        if csv_out:
            csv_out(f"tp_model_axis_{m}", r["wall_s"] * 1e6,
                    f"capacity={r['pool_tokens_capacity']}tok "
                    f"peak={r['admitted_peak_tokens']}tok "
                    f"preempt={r['preemptions']} oom={r['oom_events']}")
    base = MODEL_AXES[0]
    results["outputs_identical_to_single_device"] = all(
        outputs[m] == outputs[base] for m in MODEL_AXES)
    results["admitted_peak_scaling"] = [
        r["admitted_peak_tokens"] for r in results["meshes"]]
    results["capacity_scaling"] = [
        r["pool_tokens_capacity"] for r in results["meshes"]]
    with open(out_json, "w") as f:
        json.dump(results, f, indent=2)
    if csv_out:
        csv_out("tp_summary", 0.0,
                f"peaks={results['admitted_peak_scaling']} "
                f"identical={results['outputs_identical_to_single_device']} "
                f"-> {out_json}")
    return results


def run(csv_out) -> None:
    run_tp_scaling(csv_out=csv_out)


if __name__ == "__main__":
    run(lambda n, us, d: print(f"{n},{us:.1f},{d}"))
