"""End-to-end training driver: ~100M-param dense model, a few hundred steps.

    PYTHONPATH=src python examples/train_small.py [--steps 200]

(The assignment's train example — a small-but-real model on the synthetic
Markov pipeline, with checkpointing. On CPU this takes a few minutes.)
"""
import argparse

import jax.numpy as jnp

from repro.config.base import ArchFamily, ModelConfig, TrainConfig
from repro.models.model import build_model
from repro.training.train_loop import train


def model_100m() -> ModelConfig:
    # ~100M params: 12L, d=512, 8 heads, GQA kv=4, SwiGLU 3x512x1536
    return ModelConfig(
        name="repro-100m", family=ArchFamily.DENSE, num_layers=12,
        d_model=512, num_heads=8, num_kv_heads=4, d_ff=1536,
        vocab_size=32768, source="examples/train_small.py")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_100m.npz")
    args = ap.parse_args()

    cfg = model_100m()
    model = build_model(cfg, dtype=jnp.float32)
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params")
    t = TrainConfig(global_batch=args.batch, seq_len=args.seq,
                    steps=args.steps, lr=3e-3, warmup_steps=20, log_every=10)
    res = train(model, t, checkpoint_path=args.ckpt)
    print(f"final loss {res['losses'][-1]:.4f}  "
          f"({res['tokens_per_s']:.0f} tok/s); checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
