"""SLA-constrained serving (paper Algorithm 2 + Table II / Fig 4).

Sweeps offered load and reports SLA attainment + capacity for static vs the
combined (min(b_mem, b_SLA)) controller.

    PYTHONPATH=src python examples/sla_capacity.py
"""
from repro.config.base import ServeConfig
from repro.config.registry import get_config
from repro.serving.cost_model import CostModel, PROFILES
from repro.serving.sim import LengthDist, ServingSimulator

SLA_MS = 50.0


def run(policy: str, qps: float):
    cfg = get_config("granite-3-8b")
    cost = CostModel(cfg, PROFILES["paper-fig3"], c0_ms=28.0, c1_ms=0.225)
    serve = ServeConfig(policy=policy, b_max=256, d_sla_ms=SLA_MS,
                        eps_d_ms=3.0, max_new_tokens=256)
    sim = ServingSimulator(cfg, serve, cost,
                           LengthDist(mean_in=256, mean_out=64), seed=0)
    sim.add_requests(400, arrival_rate=qps)
    return sim.run()


def main():
    print(f"TBT SLA = {SLA_MS} ms; capacity = max qps with >=90% attainment "
          f"and bounded TTFT")
    for policy in ("static", "combined"):
        cap = 0.0
        print(f"-- {policy}")
        for qps in (1, 2, 4, 6, 8, 12, 16):
            res = run(policy, qps)
            ok = res.sla_attainment >= 0.9 and res.ttft_p90_s <= 30.0
            print(f"   qps={qps:4.1f} attain={res.sla_attainment:5.3f} "
                  f"tbt_mean={res.tbt_ms_mean:6.1f}ms "
                  f"ttft_p90={res.ttft_p90_s:6.1f}s "
                  f"mean_batch={res.mean_batch:6.1f} {'OK' if ok else 'X'}")
            if ok:
                cap = qps
        print(f"   capacity({policy}) = {cap} qps")


if __name__ == "__main__":
    main()
