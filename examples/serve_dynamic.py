"""Static vs dynamic batching head-to-head (paper Table I in miniature).

Runs the SAME workload through the vLLM-style static preset and the paper's
memory-aware controller on a deliberately tight KV pool, on a real reduced
model — then at paper scale through the calibrated simulator.

    PYTHONPATH=src python examples/serve_dynamic.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ServeConfig
from repro.config.registry import get_config
from repro.models.model import build_model
from repro.serving.cost_model import CostModel, PROFILES
from repro.serving.engine import Engine
from repro.serving.sim import LengthDist, ServingSimulator


def real_engine_comparison():
    cfg = get_config("granite-3-8b", "reduced")
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    print("== real engine (reduced model, tight 384-token pool) ==")
    for policy in ("static", "memory"):
        rng = np.random.RandomState(2)
        serve = ServeConfig(policy=policy, b_max=8, max_new_tokens=24,
                            kv_pool_tokens=384, block_size=16)
        eng = Engine(model, params, serve, max_context=64,
                     buckets=(1, 2, 4, 8), prefill_chunk=8)
        for _ in range(8):
            eng.submit(list(map(int, rng.randint(0, cfg.vocab_size, size=8))),
                       max_new_tokens=24)
        eng.run()
        s = eng.summary()
        print(f"  {policy:8s} tput={s['throughput_tok_s']:8.1f} tok/s "
              f"mean_batch={s['mean_batch']:.1f} preemptions={s['preemptions']}")


def simulator_comparison():
    cfg = get_config("granite-3-8b")   # full 8B dims
    cost = CostModel(cfg, PROFILES["a100x8"])
    print("== simulator (full 8B model, 8xA100-class, 600 requests) ==")
    for policy, b_max in (("static", 256), ("memory", 2048)):
        sim = ServingSimulator(
            cfg, ServeConfig(policy=policy, b_max=b_max, max_new_tokens=512),
            cost, LengthDist(mean_in=128, mean_out=128, fixed=True), seed=0)
        sim.add_requests(600)
        res = sim.run()
        print(f"  {policy:8s} tput={res.throughput_tok_s:9.1f} tok/s "
              f"mean_batch={res.mean_batch:.0f} tbt={res.tbt_ms_mean:.1f}ms")


if __name__ == "__main__":
    real_engine_comparison()
    simulator_comparison()
