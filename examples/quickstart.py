"""Quickstart: serve a reduced model with memory-aware dynamic batching.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ServeConfig
from repro.config.registry import get_config
from repro.models.model import build_model
from repro.serving.engine import Engine


def main():
    cfg = get_config("granite-3-8b", "reduced")
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))

    serve = ServeConfig(policy="memory",   # paper Algorithm 1
                        b_max=16, max_new_tokens=16, kv_pool_tokens=4096)
    eng = Engine(model, params, serve, max_context=128,
                 buckets=(1, 2, 4, 8, 16), prefill_chunk=16)

    rng = np.random.RandomState(0)
    handles = [eng.submit(list(map(int, rng.randint(0, cfg.vocab_size,
                                                    size=rng.randint(4, 24)))))
               for _ in range(10)]
    eng.run()

    for h in handles[:3]:
        print(f"req {h.rid}: prompt[{h.prompt_len}] -> {h.output_tokens}")
    print("summary:", {k: round(v, 2) for k, v in eng.summary().items()})


if __name__ == "__main__":
    main()
