"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# decode attention


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KV,hd,S,bs", [
    (2, 8, 2, 32, 64, 32),
    (1, 4, 4, 16, 128, 128),   # MHA-style, single block
    (3, 8, 1, 64, 96, 32),     # MQA, ragged block count
])
def test_decode_attention_sweep(B, H, KV, hd, S, bs, dtype):
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (B, H, hd), dtype)
    k = rand(ks[1], (B, S, KV, hd), dtype)
    v = rand(ks[2], (B, S, KV, hd), dtype)
    q_pos = jnp.array([S - 1, S // 2, 3][:B], jnp.int32)
    k_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    k_pos = jnp.where(k_pos <= q_pos[:, None], k_pos, -1)
    out = ops.decode_attention(q, k, v, q_pos, k_pos, block_s=bs)
    want = ref.decode_attention_ref(q, k, v, q_pos, k_pos)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


def test_decode_attention_ring_buffer_semantics():
    """Positions not slot order decide masking — emulate a wrapped ring."""
    B, H, KV, hd, S = 1, 4, 1, 16, 8
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (B, H, hd), jnp.float32)
    k = rand(ks[1], (B, S, KV, hd), jnp.float32)
    v = rand(ks[2], (B, S, KV, hd), jnp.float32)
    # ring: slots hold positions 8..15 wrapped (slot i has pos 8+((i+3) % 8))
    k_pos = jnp.array([[11, 12, 13, 14, 15, 8, 9, 10]], jnp.int32)
    q_pos = jnp.array([15], jnp.int32)
    out = ops.decode_attention(q, k, v, q_pos, k_pos, window=4, block_s=4)
    want = ref.decode_attention_ref(q, k, v, q_pos, k_pos, window=4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# flash attention (prefill)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("Tq,Tk,H,KV,hd,bq,bk,window,causal", [
    (64, 64, 8, 4, 32, 32, 32, 0, True),
    (32, 96, 4, 1, 16, 16, 32, 0, True),    # chunk continuing a cache
    (64, 64, 4, 4, 32, 64, 64, 16, True),   # sliding window
    (32, 32, 8, 2, 16, 32, 32, 0, False),   # bidirectional (encoder)
])
def test_flash_attention_sweep(Tq, Tk, H, KV, hd, bq, bk, window, causal,
                               dtype):
    B = 2
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (B, Tq, H, hd), dtype)
    k = rand(ks[1], (B, Tk, KV, hd), dtype)
    v = rand(ks[2], (B, Tk, KV, hd), dtype)
    off = Tk - Tq
    qp = jnp.broadcast_to(off + jnp.arange(Tq, dtype=jnp.int32)[None], (B, Tq))
    kp = jnp.broadcast_to(jnp.arange(Tk, dtype=jnp.int32)[None], (B, Tk))
    out = ops.flash_attention(q, k, v, qp, kp, window=window, causal=causal,
                              block_q=bq, block_k=bk)
    want = ref.flash_attention_ref(q, k, v, qp, kp, window=window,
                                   causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


# ---------------------------------------------------------------------------
# SSD intra-chunk


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,nc,Q,H,P,N", [
    (2, 3, 16, 4, 8, 12),
    (1, 1, 64, 2, 32, 16),
    (2, 4, 8, 8, 16, 8),
])
def test_ssd_intra_sweep(B, nc, Q, H, P, N, dtype):
    ks = jax.random.split(KEY, 4)
    xdt = rand(ks[0], (B, nc, Q, H, P), dtype)
    cum_a = -jnp.abs(rand(ks[1], (B, nc, Q, H), jnp.float32)).cumsum(axis=2)
    Br = rand(ks[2], (B, nc, Q, N), dtype)
    Cr = rand(ks[3], (B, nc, Q, N), dtype)
    y, s = ops.ssd_intra(xdt, cum_a, Br, Cr)
    yr, sr = ref.ssd_intra_ref(xdt, cum_a, Br, Cr)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), **tol(dtype))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), **tol(dtype))


# ---------------------------------------------------------------------------
# RG-LRU scan


@pytest.mark.parametrize("B,T,W,bw", [
    (2, 32, 256, 128),
    (1, 128, 128, 128),
    (4, 16, 512, 64),
])
def test_rglru_scan_sweep(B, T, W, bw):
    ks = jax.random.split(KEY, 3)
    a = jax.nn.sigmoid(rand(ks[0], (B, T, W), jnp.float32))
    bx = rand(ks[1], (B, T, W), jnp.float32)
    h0 = rand(ks[2], (B, W), jnp.float32)
    y, hT = ops.rglru_scan(a, bx, h0, block_w=bw)
    yr, hTr = ref.rglru_scan_ref(a, bx, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hTr), rtol=1e-5,
                               atol=1e-5)


def test_decode_attention_matches_model_semantics():
    """Kernel mask law == models.layers.attend mask law (same positions)."""
    from repro.models.layers import attend
    B, H, KV, hd, S = 2, 4, 2, 16, 32
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (B, H, hd), jnp.float32)
    k = rand(ks[1], (B, S, KV, hd), jnp.float32)
    v = rand(ks[2], (B, S, KV, hd), jnp.float32)
    q_pos = jnp.array([20, 7], jnp.int32)
    k_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    k_pos = jnp.where(k_pos <= q_pos[:, None], k_pos, -1)
    out = ops.decode_attention(q, k, v, q_pos, k_pos, block_s=8)
    want = attend(q[:, None], k, v, q_pos[:, None], k_pos).reshape(B, H, hd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# fused RMSNorm


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape,br", [
    ((2, 32, 128), 16),
    ((4, 7, 256), 128),     # rows not a block multiple (pad path)
    ((1, 1, 64), 8),
])
def test_rmsnorm_sweep(shape, br, dtype):
    ks = jax.random.split(KEY, 2)
    x = rand(ks[0], shape, dtype)
    w = rand(ks[1], (shape[-1],), jnp.float32) * 0.1
    out = ops.rmsnorm(x, w, block_rows=br)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


def test_rmsnorm_matches_model_layer():
    from repro.models.layers import rms_norm
    x = rand(KEY, (2, 8, 96), jnp.float32)
    w = rand(jax.random.fold_in(KEY, 1), (96,), jnp.float32) * 0.1
    np.testing.assert_allclose(
        np.asarray(ops.rmsnorm(x, w)), np.asarray(rms_norm(x, w)),
        rtol=1e-5, atol=1e-5)
