"""Workload generators: rate laws + trace round-trip + sim integration."""
import os

from repro.config.base import ServeConfig
from repro.config.registry import get_config
from repro.serving.cost_model import CostModel, PROFILES
from repro.serving.sim import LengthDist, ServingSimulator
from repro.serving.workload import (bursty, diurnal, feed, feed_tokens,
                                    load_trace, poisson, save_trace,
                                    shared_prefix)

L = LengthDist(mean_in=64, mean_out=64, fixed=True)


def rate_in(arrivals, t0, t1):
    n = sum(1 for t, _, _ in arrivals if t0 <= t < t1)
    return n / (t1 - t0)


def test_poisson_rate():
    arr = poisson(10.0, 2000, L, seed=0)
    assert abs(rate_in(arr, 10, 150) - 10.0) < 1.5


def test_bursty_rates_differ():
    arr = bursty(base_rate=2.0, burst_rate=40.0, period_s=100.0, duty=0.2,
                 n=4000, lengths=L, seed=0)
    # burst window [0,20) vs quiet [30,90) of the first period
    assert rate_in(arr, 0, 20) > 5 * rate_in(arr, 30, 90)


def test_diurnal_modulates():
    arr = diurnal(mean_rate=10.0, amplitude=0.9, period_s=200.0, n=4000,
                  lengths=L, seed=0)
    peak = rate_in(arr, 40, 60)     # sin peak near t=50
    trough = rate_in(arr, 140, 160)  # sin trough near t=150
    assert peak > 2 * trough


def test_trace_roundtrip(tmp_path):
    arr = poisson(5.0, 50, L, seed=1)
    p = os.path.join(tmp_path, "trace.jsonl")
    save_trace(p, arr)
    assert load_trace(p) == [(t, li, lo) for t, li, lo in arr]


def test_feed_runs_simulator():
    cfg = get_config("granite-3-8b")
    cost = CostModel(cfg, PROFILES["a100x8"])
    sim = ServingSimulator(
        cfg, ServeConfig(policy="memory", b_max=256, max_new_tokens=128),
        cost, L, seed=0)
    feed(sim, bursty(2.0, 20.0, 30.0, 0.3, 150, L, seed=2))
    res = sim.run()
    assert res.finished == 150


# ---------------------------------------------------------------------------
# shared-prefix token workload (DESIGN §10)


def test_shared_prefix_pool_and_turn_structure():
    arr = shared_prefix(rate=5.0, n=200, vocab_size=500,
                        n_system_prompts=3, system_len=32, user_len=(4, 8),
                        p_followup=0.6, max_turns=4, seed=0)
    assert len(arr) == 200
    assert arr == sorted(arr, key=lambda a: a[0])
    # every prompt opens with one of the pool's system prompts
    openers = {tuple(toks[:32]) for _, toks, _ in arr}
    assert len(openers) == 3
    # multi-turn re-arrivals exist: some prompt strictly extends another
    prompts = sorted((toks for _, toks, _ in arr), key=len)
    extended = any(len(a) < len(b) and b[:len(a)] == a
                   for a in prompts[:20] for b in prompts[-20:])
    assert extended
    # output lengths positive
    assert all(lo >= 1 for _, _, lo in arr)


def test_shared_prefix_deterministic():
    kw = dict(rate=3.0, n=50, vocab_size=300, seed=7)
    assert shared_prefix(**kw) == shared_prefix(**kw)
    assert shared_prefix(**{**kw, "seed": 8}) != shared_prefix(**kw)


def test_feed_tokens_runs_simulator_with_hits():
    cfg = get_config("granite-3-8b")
    cost = CostModel(cfg, PROFILES["a100x8"])
    serve = ServeConfig(policy="memory", b_max=64, max_new_tokens=32,
                        kv_pool_tokens=65536, chunked_prefill=True,
                        paged_kv=True, prefix_cache=True)
    sim = ServingSimulator(cfg, serve, cost, L, seed=0, prefill_chunk=64)
    arr = shared_prefix(rate=5.0, n=120, vocab_size=cfg.vocab_size,
                        n_system_prompts=2, system_len=64,
                        p_followup=0.6, max_turns=4, turn_gap_s=30.0,
                        seed=1)
    feed_tokens(sim, arr)
    res = sim.run()
    assert res.finished == 120
    assert res.prefix_hit_tokens > 0
    assert 0.0 < res.prefix_hit_rate <= 1.0
