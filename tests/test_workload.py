"""Workload generators: rate laws + trace round-trip + sim integration."""
import os

from repro.config.base import ServeConfig
from repro.config.registry import get_config
from repro.serving.cost_model import CostModel, PROFILES
from repro.serving.sim import LengthDist, ServingSimulator
from repro.serving.workload import (bursty, diurnal, feed, load_trace,
                                    poisson, save_trace)

L = LengthDist(mean_in=64, mean_out=64, fixed=True)


def rate_in(arrivals, t0, t1):
    n = sum(1 for t, _, _ in arrivals if t0 <= t < t1)
    return n / (t1 - t0)


def test_poisson_rate():
    arr = poisson(10.0, 2000, L, seed=0)
    assert abs(rate_in(arr, 10, 150) - 10.0) < 1.5


def test_bursty_rates_differ():
    arr = bursty(base_rate=2.0, burst_rate=40.0, period_s=100.0, duty=0.2,
                 n=4000, lengths=L, seed=0)
    # burst window [0,20) vs quiet [30,90) of the first period
    assert rate_in(arr, 0, 20) > 5 * rate_in(arr, 30, 90)


def test_diurnal_modulates():
    arr = diurnal(mean_rate=10.0, amplitude=0.9, period_s=200.0, n=4000,
                  lengths=L, seed=0)
    peak = rate_in(arr, 40, 60)     # sin peak near t=50
    trough = rate_in(arr, 140, 160)  # sin trough near t=150
    assert peak > 2 * trough


def test_trace_roundtrip(tmp_path):
    arr = poisson(5.0, 50, L, seed=1)
    p = os.path.join(tmp_path, "trace.jsonl")
    save_trace(p, arr)
    assert load_trace(p) == [(t, li, lo) for t, li, lo in arr]


def test_feed_runs_simulator():
    cfg = get_config("granite-3-8b")
    cost = CostModel(cfg, PROFILES["a100x8"])
    sim = ServingSimulator(
        cfg, ServeConfig(policy="memory", b_max=256, max_new_tokens=128),
        cost, L, seed=0)
    feed(sim, bursty(2.0, 20.0, 30.0, 0.3, 150, L, seed=2))
    res = sim.run()
    assert res.finished == 150
