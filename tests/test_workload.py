"""Workload generators: rate laws + trace round-trip + sim integration."""
import os

from repro.config.base import ServeConfig
from repro.config.registry import get_config
from repro.serving.cost_model import CostModel, PROFILES
from repro.serving.sim import LengthDist, ServingSimulator
import math

from repro.serving.workload import (bursty, diurnal, feed, feed_tokens,
                                    load_trace, poisson, save_trace,
                                    shared_prefix)

L = LengthDist(mean_in=64, mean_out=64, fixed=True)


def rate_in(arrivals, t0, t1):
    n = sum(1 for t, _, _ in arrivals if t0 <= t < t1)
    return n / (t1 - t0)


def test_poisson_rate():
    arr = poisson(10.0, 2000, L, seed=0)
    assert abs(rate_in(arr, 10, 150) - 10.0) < 1.5


def test_bursty_rates_differ():
    arr = bursty(base_rate=2.0, burst_rate=40.0, period_s=100.0, duty=0.2,
                 n=4000, lengths=L, seed=0)
    # burst window [0,20) vs quiet [30,90) of the first period
    assert rate_in(arr, 0, 20) > 5 * rate_in(arr, 30, 90)


def test_diurnal_modulates():
    arr = diurnal(mean_rate=10.0, amplitude=0.9, period_s=200.0, n=4000,
                  lengths=L, seed=0)
    peak = rate_in(arr, 40, 60)     # sin peak near t=50
    trough = rate_in(arr, 140, 160)  # sin trough near t=150
    assert peak > 2 * trough


def test_bursty_thinning_matches_rate_law():
    """Lewis–Shedler thinning: realized per-window rates match lambda(t).

    Discriminating regime: quiet gaps (mean 2 s) dwarf the 1 s burst
    windows, so the pre-fix sampler (each gap drawn from lambda at the
    CURRENT arrival instant) stepped clean over most bursts and grossly
    undershot burst_rate — this pins the thinning fix."""
    base, burst, period, duty = 0.5, 50.0, 10.0, 0.1
    arr = bursty(base, burst, period, duty, n=3000, lengths=L, seed=3)
    nper = int(arr[-1][0] // period)
    assert nper >= 10
    b_n = q_n = 0
    for k in range(nper):
        t0 = k * period
        b_n += sum(1 for t, _, _ in arr if t0 <= t < t0 + duty * period)
        q_n += sum(1 for t, _, _ in arr
                   if t0 + duty * period <= t < t0 + period)
    b_rate = b_n / (nper * duty * period)
    q_rate = q_n / (nper * (1 - duty) * period)
    assert abs(b_rate - burst) / burst < 0.15, (b_rate, burst)
    assert abs(q_rate - base) / base < 0.25, (q_rate, base)


def test_diurnal_thinning_matches_rate_law():
    """Per-phase-window realized rates match the sinusoidal lambda(t)
    within tolerance, peak and trough alike."""
    mean, amp, period = 10.0, 0.8, 50.0
    arr = diurnal(mean, amp, period, n=6000, lengths=L, seed=4)
    nper = int(arr[-1][0] // period)
    assert nper >= 8

    def lam(t):
        return max(mean * (1 + amp * math.sin(2 * math.pi * t / period)),
                   1e-3)

    for p0, p1 in ((0.2, 0.3), (0.7, 0.8)):   # sin peak / trough phases
        n_obs = sum(1 for t, _, _ in arr
                    if (t % period) / period >= p0
                    and (t % period) / period < p1
                    and t < nper * period)
        width = (p1 - p0) * period
        expect = nper * sum(lam((p0 + (i + 0.5) / 200 * (p1 - p0))
                                * period) for i in range(200)) * width / 200
        assert abs(n_obs - expect) / expect < 0.2, (p0, n_obs, expect)


def test_trace_roundtrip(tmp_path):
    arr = poisson(5.0, 50, L, seed=1)
    p = os.path.join(tmp_path, "trace.jsonl")
    save_trace(p, arr)
    assert load_trace(p) == [(t, li, lo) for t, li, lo in arr]


def test_feed_runs_simulator():
    cfg = get_config("granite-3-8b")
    cost = CostModel(cfg, PROFILES["a100x8"])
    sim = ServingSimulator(
        cfg, ServeConfig(policy="memory", b_max=256, max_new_tokens=128),
        cost, L, seed=0)
    feed(sim, bursty(2.0, 20.0, 30.0, 0.3, 150, L, seed=2))
    res = sim.run()
    assert res.finished == 150


def test_feed_double_feed_no_rid_collision():
    """Regression: feed() used to restart rids at 0 and re-extend `_all`
    with the WHOLE waiting queue, so a second feed (or feeding a sim that
    already held requests) produced rid collisions and duplicate `_all`
    entries, silently corrupting TTFT/goodput aggregation."""
    cfg = get_config("granite-3-8b")
    cost = CostModel(cfg, PROFILES["a100x8"])
    sim = ServingSimulator(
        cfg, ServeConfig(policy="memory", b_max=256, max_new_tokens=64),
        cost, L, seed=0)
    feed(sim, poisson(5.0, 40, L, seed=1))
    feed(sim, poisson(5.0, 35, L, seed=2))
    rids = [r.rid for r in sim._all]
    assert len(rids) == 75 and len(set(rids)) == 75
    assert len(sim.waiting) == 75
    res = sim.run()
    assert res.finished == 75
    # SLA checks disabled: every finished request meets the goodput SLA
    assert res.sla_requests_met == 75
    assert res.request_sla_attainment == 1.0


def test_mixed_feeders_share_rid_space():
    """add_requests + feed + feed_tokens on one sim: rids never collide
    and `_all` holds each request exactly once."""
    cfg = get_config("granite-3-8b")
    cost = CostModel(cfg, PROFILES["a100x8"])
    sim = ServingSimulator(
        cfg, ServeConfig(policy="memory", b_max=256, max_new_tokens=32),
        cost, L, seed=0)
    sim.add_requests(10, arrival_rate=4.0)
    sim.add_requests(10, arrival_rate=4.0)
    feed(sim, poisson(5.0, 10, L, seed=3))
    feed_tokens(sim, shared_prefix(rate=5.0, n=10, vocab_size=500, seed=4))
    rids = [r.rid for r in sim._all]
    assert len(rids) == 40 and len(set(rids)) == 40
    res = sim.run()
    assert res.finished == 40


# ---------------------------------------------------------------------------
# shared-prefix token workload (DESIGN §10)


def test_shared_prefix_pool_and_turn_structure():
    arr = shared_prefix(rate=5.0, n=200, vocab_size=500,
                        n_system_prompts=3, system_len=32, user_len=(4, 8),
                        p_followup=0.6, max_turns=4, seed=0)
    assert len(arr) == 200
    assert arr == sorted(arr, key=lambda a: a[0])
    # every prompt opens with one of the pool's system prompts
    openers = {tuple(toks[:32]) for _, toks, _ in arr}
    assert len(openers) == 3
    # multi-turn re-arrivals exist: some prompt strictly extends another
    prompts = sorted((toks for _, toks, _ in arr), key=len)
    extended = any(len(a) < len(b) and b[:len(a)] == a
                   for a in prompts[:20] for b in prompts[-20:])
    assert extended
    # output lengths positive
    assert all(lo >= 1 for _, _, lo in arr)


def test_shared_prefix_deterministic():
    kw = dict(rate=3.0, n=50, vocab_size=300, seed=7)
    assert shared_prefix(**kw) == shared_prefix(**kw)
    assert shared_prefix(**{**kw, "seed": 8}) != shared_prefix(**kw)


def test_feed_tokens_runs_simulator_with_hits():
    cfg = get_config("granite-3-8b")
    cost = CostModel(cfg, PROFILES["a100x8"])
    serve = ServeConfig(policy="memory", b_max=64, max_new_tokens=32,
                        kv_pool_tokens=65536, chunked_prefill=True,
                        paged_kv=True, prefix_cache=True)
    sim = ServingSimulator(cfg, serve, cost, L, seed=0, prefill_chunk=64)
    arr = shared_prefix(rate=5.0, n=120, vocab_size=cfg.vocab_size,
                        n_system_prompts=2, system_len=64,
                        p_followup=0.6, max_turns=4, turn_gap_s=30.0,
                        seed=1)
    feed_tokens(sim, arr)
    res = sim.run()
    assert res.finished == 120
    assert res.prefix_hit_tokens > 0
    assert 0.0 < res.prefix_hit_rate <= 1.0
