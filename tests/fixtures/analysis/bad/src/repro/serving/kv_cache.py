"""Bad fixture allocator (the violations live in engine.py)."""


class BlockManager:
    def __init__(self):
        self.tables = {}
        self.ref = {}
        self._free = []
