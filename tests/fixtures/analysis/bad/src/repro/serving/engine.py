"""Bad fixture: one seeded violation per AST rule, at known lines."""
import jax
import numpy as np


class Engine:
    def __init__(self, blocks):
        self.blocks = blocks
        self.finished = 0
        self.preemptions = 0

    def step(self, x):
        y = jax.block_until_ready(x)          # host-sync: line 13
        n = int(y.item())                     # host-sync: line 14
        h = np.asarray(y)                     # host-sync: line 15
        return n, h

    def evict(self, rid, b):
        self.blocks.ref[b] -= 1               # allocator: line 19
        self.blocks.tables[rid].append(b)     # allocator: line 20
        del self.blocks.tables[rid]           # allocator: line 21

    def summary(self):
        return {
            "finished": self.finished,
            "preemptions": self.preemptions,  # counter-parity: line 26
        }
