"""Bad fixture: SimResult carries a sim-only scalar the engine lacks."""
import dataclasses
from typing import List


@dataclasses.dataclass
class SimResult:
    finished: int = 0
    oom_events: int = 0
    batch_trace: List[int] = dataclasses.field(default_factory=list)

    @property
    def throughput(self) -> float:
        return float(self.finished)
