"""Bad fixture: a dead field, an unwired field, an undocumented field."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    b_max: int = 16
    b_min: int = 1                 # read but never wired through the CLI
    scheduling_interval: int = 1   # dead: nothing reads it
    eps_m: float = 0.05            # wired + read but undocumented
