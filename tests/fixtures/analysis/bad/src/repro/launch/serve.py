"""Bad fixture CLI: wires b_max and eps_m only."""
from repro.config.base import ServeConfig


def main(args):
    serve = ServeConfig(b_max=args.b_max, eps_m=args.eps_m)
    return serve.b_max + serve.b_min + serve.eps_m
