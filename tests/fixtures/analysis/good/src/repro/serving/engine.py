"""Good fixture: a miniature engine with no lint violations."""
import jax


class Engine:
    def __init__(self, blocks):
        self.blocks = blocks
        self.finished = 0

    def warmup(self, x):
        # the lone sync point; absorbed by the fixture allowlist
        return jax.block_until_ready(x)

    def free(self, rid):
        # mutation through the manager API, not its internals
        self.blocks.free(rid)
        n = len(self.blocks.tables)  # reads are fine
        return n

    def summary(self):
        return {
            "finished": self.finished,
        }
