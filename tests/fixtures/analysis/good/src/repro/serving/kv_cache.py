"""Good fixture: the allocator owns and mutates its own state."""


class BlockManager:
    def __init__(self):
        self.tables = {}
        self.ref = {}
        self._free = []

    def free(self, rid):
        for b in self.tables.pop(rid, []):
            self.ref[b] -= 1
            if self.ref[b] == 0:
                self._free.append(b)
