"""Good fixture: SimResult mirrors Engine.summary() exactly."""
import dataclasses
from typing import List


@dataclasses.dataclass
class SimResult:
    finished: int = 0
    batch_trace: List[int] = dataclasses.field(default_factory=list)
