"""Good fixture: the CLI wires every ServeConfig field."""
from repro.config.base import ServeConfig


def main(args):
    serve = ServeConfig(b_max=args.b_max)
    return serve.b_max
