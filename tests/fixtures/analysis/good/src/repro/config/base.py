"""Good fixture: every ServeConfig field is read, wired, documented."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    b_max: int = 16
