"""BlockManager allocator invariants (unit + stateful property tests)."""
from _hypothesis_compat import given, settings, strategies as st

from repro.serving.kv_cache import BlockManager


def test_basic_alloc_free():
    bm = BlockManager(total_tokens=160, block_size=16)
    assert bm.num_blocks == 10
    assert bm.allocate(1, 0, 33)            # 3 blocks
    assert bm.free_blocks == 7
    assert bm.allocate(1, 33, 1) is True    # fits in existing 3rd block? 34>48? no: 34 tokens -> 3 blocks
    assert bm.free_blocks == 7
    bm.free(1)
    assert bm.free_blocks == 10


def test_allocate_rejects_when_full():
    bm = BlockManager(total_tokens=64, block_size=16)
    assert bm.allocate(1, 0, 64)
    assert not bm.allocate(2, 0, 1)
    bm.free(1)
    assert bm.allocate(2, 0, 1)


def test_incremental_growth_accounting():
    bm = BlockManager(total_tokens=160, block_size=16)
    bm.allocate(7, 0, 16)
    assert bm.used_tokens_of(7) == 16
    for t in range(16, 40):
        bm.allocate(7, t, 1)
    assert bm.used_tokens_of(7) == 48       # ceil(41/16)=3 blocks


@given(st.lists(st.tuples(st.integers(0, 9), st.integers(1, 40),
                          st.booleans()), max_size=80))
@settings(max_examples=120, deadline=None)
def test_never_leaks_or_double_allocates(ops):
    bm = BlockManager(total_tokens=320, block_size=16)
    lens = {}
    for rid, n, free in ops:
        if free:
            bm.free(rid)
            lens.pop(rid, None)
        else:
            cur = lens.get(rid, 0)
            if bm.allocate(rid, cur, n):
                lens[rid] = cur + n
        # invariant: free + owned == total
        owned = sum(len(t) for t in bm.tables.values())
        assert owned + bm.free_blocks == bm.num_blocks
        # every request has enough blocks for its tokens
        for r, ln in lens.items():
            assert len(bm.tables.get(r, ())) * 16 >= ln
    # no block owned twice
    all_blocks = [b for t in bm.tables.values() for b in t] + bm._free
    assert len(all_blocks) == len(set(all_blocks)) == bm.num_blocks
