"""BlockManager allocator invariants (unit + stateful property tests)."""
from _hypothesis_compat import given, settings, strategies as st

from repro.serving.kv_cache import BlockManager


def test_basic_alloc_free():
    bm = BlockManager(total_tokens=160, block_size=16)
    assert bm.num_blocks == 10
    assert bm.allocate(1, 0, 33)            # 3 blocks
    assert bm.free_blocks == 7
    assert bm.allocate(1, 33, 1) is True    # fits in existing 3rd block? 34>48? no: 34 tokens -> 3 blocks
    assert bm.free_blocks == 7
    bm.free(1)
    assert bm.free_blocks == 10


def test_allocate_rejects_when_full():
    bm = BlockManager(total_tokens=64, block_size=16)
    assert bm.allocate(1, 0, 64)
    assert not bm.allocate(2, 0, 1)
    bm.free(1)
    assert bm.allocate(2, 0, 1)


def test_incremental_growth_accounting():
    bm = BlockManager(total_tokens=160, block_size=16)
    bm.allocate(7, 0, 16)
    assert bm.used_tokens_of(7) == 16
    for t in range(16, 40):
        bm.allocate(7, t, 1)
    assert bm.used_tokens_of(7) == 48       # ceil(41/16)=3 blocks


@given(st.lists(st.tuples(st.integers(0, 9), st.integers(1, 40),
                          st.booleans()), max_size=80))
@settings(max_examples=120, deadline=None)
def test_never_leaks_or_double_allocates(ops):
    bm = BlockManager(total_tokens=320, block_size=16)
    lens = {}
    for rid, n, free in ops:
        if free:
            bm.free(rid)
            lens.pop(rid, None)
        else:
            cur = lens.get(rid, 0)
            if bm.allocate(rid, cur, n):
                lens[rid] = cur + n
        # invariant: free + owned == total
        owned = sum(len(t) for t in bm.tables.values())
        assert owned + bm.free_blocks == bm.num_blocks
        # every request has enough blocks for its tokens
        for r, ln in lens.items():
            assert len(bm.tables.get(r, ())) * 16 >= ln
    # no block owned twice
    all_blocks = [b for t in bm.tables.values() for b in t] + bm._free
    assert len(all_blocks) == len(set(all_blocks)) == bm.num_blocks


# ---------------------------------------------------------------------------
# prefix-sharing refcount invariants (DESIGN §10)


def _check_refcount_invariants(bm: BlockManager):
    """Every block is in exactly one of {free list, evictable cache,
    referenced-by-tables}; refcounts equal table occurrences; cached blocks
    are never referenced (evict-while-referenced impossible by state)."""
    occurrences = {}
    for t in bm.tables.values():
        for b in t:
            occurrences[b] = occurrences.get(b, 0) + 1
    referenced = set(occurrences)
    free = set(bm._free)
    cached = set(bm._cached)
    assert not (free & cached) and not (free & referenced) \
        and not (cached & referenced)
    assert len(free) + len(cached) + len(referenced) == bm.num_blocks
    assert len(bm._free) == len(free)          # no duplicates on free list
    for b, n in occurrences.items():
        assert bm.ref[b] == n
    # distinct-referenced + distinct-free partition == pool (the "sum of
    # refcounts" invariant, with shared blocks counted once)
    assert bm.free_blocks == len(free) + len(cached)


@given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 4),
                          st.integers(1, 40)), max_size=60))
@settings(max_examples=120, deadline=None)
def test_prefix_refcount_invariants(ops):
    """Random acquire/commit/allocate/free/COW interleavings can never
    leak a block, double-own a block, or evict a referenced block."""
    bm = BlockManager(total_tokens=320, block_size=16, prefix_cache=True)
    prompts = {}
    for rid, op, n in ops:
        if op == 0:          # admit: prefix-match then allocate the rest
            if rid in bm.tables:
                continue
            p = toks(16 + n, seed=n % 7)
            cached = bm.acquire_prefix(rid, p)
            if bm.allocate(rid, cached, len(p) + 1 - cached):
                prompts[rid] = p
            else:
                bm.free(rid)
                prompts.pop(rid, None)
        elif op == 1:        # prefill progress: register full blocks
            if rid in prompts:
                bm.commit_prefill(rid, prompts[rid], min(n, len(prompts[rid])))
        elif op == 2:        # decode grow
            if rid in bm.tables:
                bm.allocate(rid, len(bm.tables[rid]) * 16, 1)
        elif op == 3:        # finish/evict: decref
            bm.free(rid)
            prompts.pop(rid, None)
        else:                # double-free must be harmless
            bm.free(rid)
            bm.free(rid)
            prompts.pop(rid, None)
        if rid in bm.tables and bm.physical_free_blocks + bm.cached_blocks:
            bm.cow_range(rid, 0, min(n, len(bm.tables[rid]) * 16))
        _check_refcount_invariants(bm)
    for rid in list(bm.tables):
        bm.free(rid)
    _check_refcount_invariants(bm)
    assert bm.free_blocks == bm.num_blocks     # nothing leaked


def toks(n, seed=0):
    import random
    rng = random.Random(seed)
    return [rng.randrange(997) for _ in range(n)]


# ---------------------------------------------------------------------------
# two-tier swap-ledger invariants (DESIGN §11)


def _check_two_tier_invariants(bm: BlockManager):
    """Device pool: free + evictable + referenced == num_blocks (the §10
    invariant, undisturbed by swapping). Host pool: swap-free + ledgered
    == swap_space_blocks, with no block in both states and no rid both
    device-resident and swapped."""
    _check_refcount_invariants(bm)
    host_free = set(bm._swap_free)
    ledgered = [b for t in bm.swapped_tables.values() for b in t]
    assert len(bm._swap_free) == len(host_free)    # no host double-free
    assert len(ledgered) == len(set(ledgered))     # no host double-own
    assert not (host_free & set(ledgered))
    assert len(host_free) + len(ledgered) == bm.swap_space_blocks
    assert not (set(bm.tables) & set(bm.swapped_tables))
    assert bm.swapped_blocks == len(ledgered)


@given(st.lists(st.tuples(st.integers(0, 4), st.integers(0, 5),
                          st.integers(1, 40)), max_size=70))
@settings(max_examples=120, deadline=None)
def test_swap_ledger_invariants(ops):
    """Random interleavings of admit/commit/grow/free/swap-out/swap-in —
    with the prefix cache live underneath — can never break either pool's
    conservation, and a swapped rid's ledger survives arbitrary device
    churn until its own swap-in."""
    bm = BlockManager(total_tokens=320, block_size=16, prefix_cache=True,
                      swap_space_blocks=12)
    prompts = {}
    for rid, op, n in ops:
        if op == 0:          # admit
            if rid in bm.tables or rid in bm.swapped_tables:
                continue
            p = toks(16 + n, seed=n % 7)
            cached = bm.acquire_prefix(rid, p)
            if bm.allocate(rid, cached, len(p) + 1 - cached):
                prompts[rid] = p
            else:
                bm.free(rid)
                prompts.pop(rid, None)
        elif op == 1:        # prefill progress
            if rid in prompts and rid in bm.tables:
                bm.commit_prefill(rid, prompts[rid],
                                  min(n, len(prompts[rid])))
        elif op == 2:        # decode grow
            if rid in bm.tables:
                bm.allocate(rid, len(bm.tables[rid]) * 16, 1)
        elif op == 3:        # finish / recompute-evict
            bm.free(rid)
            prompts.pop(rid, None)
        elif op == 4:        # swap-out (the engine checks can_swap_out)
            if rid in bm.tables and bm.can_swap_out(rid):
                pairs = bm.swap_out(rid)
                assert bm.swapped_tables[rid] == [h for _, h in pairs]
        else:                # swap-in
            if rid in bm.swapped_tables and bm.can_swap_in(rid):
                nb = len(bm.swapped_tables[rid])
                pairs = bm.swap_in(rid)
                assert len(pairs) == len(bm.tables[rid]) == nb
        _check_two_tier_invariants(bm)
    for rid in list(bm.tables) + list(bm.swapped_tables):
        bm.free(rid)
    _check_two_tier_invariants(bm)
    assert bm.free_blocks == bm.num_blocks
    assert bm.host_free_blocks == bm.swap_space_blocks


@given(st.lists(st.tuples(st.integers(0, 4), st.integers(0, 3),
                          st.integers(1, 24)), max_size=60))
@settings(max_examples=100, deadline=None)
def test_swap_roundtrip_restores_pool_contents(ops):
    """Byte-identity at the allocator contract level: emulate the pool as
    one payload per block, apply exactly the copy pairs swap_out/swap_in
    return, clobber freed device blocks on reuse — every resident
    request's visible contents survive any number of swap round trips."""
    bm = BlockManager(total_tokens=160, block_size=16, swap_space_blocks=8)
    dev, host = {}, {}            # block -> payload
    expect = {}                   # rid -> expected payload list
    for rid, op, n in ops:
        if op == 0:              # admit/grow: fresh payloads for new blocks
            if rid in bm.swapped_tables:
                continue
            have = len(bm.tables.get(rid, ()))
            if bm.allocate(rid, have * 16, n):
                tbl = bm.tables[rid]
                exp = expect.setdefault(rid, [])
                for k in range(have, len(tbl)):
                    payload = (rid, len(exp))
                    dev[tbl[k]] = payload     # overwrites any stale tenant
                    exp.append(payload)
        elif op == 1:            # free
            for b in bm.free(rid):
                dev.pop(b, None)
            expect.pop(rid, None)
        elif op == 2:            # swap-out: copy BEFORE device reuse
            if rid in bm.tables and bm.can_swap_out(rid):
                for d, h in bm.swap_out(rid):
                    host[h] = dev.pop(d)
        else:                    # swap-in
            if rid in bm.swapped_tables and bm.can_swap_in(rid):
                for h, d in bm.swap_in(rid):
                    dev[d] = host.pop(h)
        # every resident table reads back its own payloads, in order
        for r, tbl in bm.tables.items():
            assert [dev[b] for b in tbl] == expect[r], r
        # every ledger holds the swapped rid's payloads, in order
        for r, ledger in bm.swapped_tables.items():
            assert [host[h] for h in ledger] == expect[r], r


def test_shared_ref_blocks_are_never_swappable():
    """Regression (DESIGN §11): a victim holding any ref > 1 block must
    fall back to recompute — its shared blocks' content must stay
    device-resident for the other owners."""
    bm = BlockManager(total_tokens=320, block_size=16, prefix_cache=True,
                      swap_space_blocks=8)
    p = toks(40)
    bm.allocate(1, 0, 41)
    bm.commit_prefill(1, p, 40)
    bm.acquire_prefix(2, p)                   # blocks shared, ref == 2
    bm.allocate(2, 32, 9)
    assert not bm.can_swap_out(1)
    assert not bm.can_swap_out(2)
    bm.free(2)                                # last other ref drops
    assert bm.can_swap_out(1)
    pairs = bm.swap_out(1)
    # swapped-out content leaves the prefix index: a new probe must miss
    assert bm.acquire_prefix(3, p) == 0
    assert len(pairs) == 3 and bm.swapped_blocks == 3
