"""BlockManager allocator invariants (unit + stateful property tests)."""
from _hypothesis_compat import given, settings, strategies as st

from repro.serving.kv_cache import BlockManager


def test_basic_alloc_free():
    bm = BlockManager(total_tokens=160, block_size=16)
    assert bm.num_blocks == 10
    assert bm.allocate(1, 0, 33)            # 3 blocks
    assert bm.free_blocks == 7
    assert bm.allocate(1, 33, 1) is True    # fits in existing 3rd block? 34>48? no: 34 tokens -> 3 blocks
    assert bm.free_blocks == 7
    bm.free(1)
    assert bm.free_blocks == 10


def test_allocate_rejects_when_full():
    bm = BlockManager(total_tokens=64, block_size=16)
    assert bm.allocate(1, 0, 64)
    assert not bm.allocate(2, 0, 1)
    bm.free(1)
    assert bm.allocate(2, 0, 1)


def test_incremental_growth_accounting():
    bm = BlockManager(total_tokens=160, block_size=16)
    bm.allocate(7, 0, 16)
    assert bm.used_tokens_of(7) == 16
    for t in range(16, 40):
        bm.allocate(7, t, 1)
    assert bm.used_tokens_of(7) == 48       # ceil(41/16)=3 blocks


@given(st.lists(st.tuples(st.integers(0, 9), st.integers(1, 40),
                          st.booleans()), max_size=80))
@settings(max_examples=120, deadline=None)
def test_never_leaks_or_double_allocates(ops):
    bm = BlockManager(total_tokens=320, block_size=16)
    lens = {}
    for rid, n, free in ops:
        if free:
            bm.free(rid)
            lens.pop(rid, None)
        else:
            cur = lens.get(rid, 0)
            if bm.allocate(rid, cur, n):
                lens[rid] = cur + n
        # invariant: free + owned == total
        owned = sum(len(t) for t in bm.tables.values())
        assert owned + bm.free_blocks == bm.num_blocks
        # every request has enough blocks for its tokens
        for r, ln in lens.items():
            assert len(bm.tables.get(r, ())) * 16 >= ln
    # no block owned twice
    all_blocks = [b for t in bm.tables.values() for b in t] + bm._free
    assert len(all_blocks) == len(set(all_blocks)) == bm.num_blocks


# ---------------------------------------------------------------------------
# prefix-sharing refcount invariants (DESIGN §10)


def _check_refcount_invariants(bm: BlockManager):
    """Every block is in exactly one of {free list, evictable cache,
    referenced-by-tables}; refcounts equal table occurrences; cached blocks
    are never referenced (evict-while-referenced impossible by state)."""
    occurrences = {}
    for t in bm.tables.values():
        for b in t:
            occurrences[b] = occurrences.get(b, 0) + 1
    referenced = set(occurrences)
    free = set(bm._free)
    cached = set(bm._cached)
    assert not (free & cached) and not (free & referenced) \
        and not (cached & referenced)
    assert len(free) + len(cached) + len(referenced) == bm.num_blocks
    assert len(bm._free) == len(free)          # no duplicates on free list
    for b, n in occurrences.items():
        assert bm.ref[b] == n
    # distinct-referenced + distinct-free partition == pool (the "sum of
    # refcounts" invariant, with shared blocks counted once)
    assert bm.free_blocks == len(free) + len(cached)


@given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 4),
                          st.integers(1, 40)), max_size=60))
@settings(max_examples=120, deadline=None)
def test_prefix_refcount_invariants(ops):
    """Random acquire/commit/allocate/free/COW interleavings can never
    leak a block, double-own a block, or evict a referenced block."""
    bm = BlockManager(total_tokens=320, block_size=16, prefix_cache=True)
    prompts = {}
    for rid, op, n in ops:
        if op == 0:          # admit: prefix-match then allocate the rest
            if rid in bm.tables:
                continue
            p = toks(16 + n, seed=n % 7)
            cached = bm.acquire_prefix(rid, p)
            if bm.allocate(rid, cached, len(p) + 1 - cached):
                prompts[rid] = p
            else:
                bm.free(rid)
                prompts.pop(rid, None)
        elif op == 1:        # prefill progress: register full blocks
            if rid in prompts:
                bm.commit_prefill(rid, prompts[rid], min(n, len(prompts[rid])))
        elif op == 2:        # decode grow
            if rid in bm.tables:
                bm.allocate(rid, len(bm.tables[rid]) * 16, 1)
        elif op == 3:        # finish/evict: decref
            bm.free(rid)
            prompts.pop(rid, None)
        else:                # double-free must be harmless
            bm.free(rid)
            bm.free(rid)
            prompts.pop(rid, None)
        if rid in bm.tables and bm.physical_free_blocks + bm.cached_blocks:
            bm.cow_range(rid, 0, min(n, len(bm.tables[rid]) * 16))
        _check_refcount_invariants(bm)
    for rid in list(bm.tables):
        bm.free(rid)
    _check_refcount_invariants(bm)
    assert bm.free_blocks == bm.num_blocks     # nothing leaked


def toks(n, seed=0):
    import random
    rng = random.Random(seed)
    return [rng.randrange(997) for _ in range(n)]
