"""Trace schema persistence + per-request goodput SLA verdicts
(DESIGN §15): versioned save/load roundtrip for length- and token-level
streams, strict line validation with path:line errors, out-of-order
sorting, the bundled reference-trace generator's conversation structure,
and goodput accounting through the simulator."""
import json

import pytest

from repro.config.base import ServeConfig
from repro.config.registry import get_config
from repro.serving.cost_model import CostModel, PROFILES
from repro.serving.request import Request
from repro.serving.sim import LengthDist, ServingSimulator
from repro.serving.workload import (TRACE_SCHEMA, TRACE_VERSION, TraceEvent,
                                    TraceFormatError, feed_trace, load_trace,
                                    load_trace_events, poisson,
                                    reference_trace, save_trace,
                                    shared_prefix, trace_prompts)

L = LengthDist(mean_in=48, mean_out=24, fixed=True)


def _sim(serve=None):
    cfg = get_config("granite-3-8b")
    cost = CostModel(cfg, PROFILES["a100x8"])
    serve = serve or ServeConfig(policy="memory", b_max=64,
                                 max_new_tokens=64)
    return ServingSimulator(cfg, serve, cost, L, seed=0)


# ---------------------------------------------------------------------------
# persistence: versioned roundtrip for both stream kinds


def test_roundtrip_lengths(tmp_path):
    arr = poisson(5.0, 50, L, seed=1)
    p = str(tmp_path / "lengths.jsonl")
    save_trace(p, arr)
    assert load_trace(p) == arr
    header = json.loads(open(p).readline())
    assert header == {"schema": TRACE_SCHEMA, "version": TRACE_VERSION,
                      "kind": "lengths"}


def test_roundtrip_tokens(tmp_path):
    arr = shared_prefix(rate=5.0, n=40, vocab_size=300, seed=2)
    p = str(tmp_path / "tokens.jsonl")
    save_trace(p, arr)
    assert load_trace(p) == arr
    assert json.loads(open(p).readline())["kind"] == "tokens"


def test_roundtrip_events_keeps_parent_links(tmp_path):
    events = reference_trace(30, seed=5, vocab_size=200, p_followup=0.7)
    p = str(tmp_path / "ref.jsonl")
    save_trace(p, events)
    assert load_trace_events(p) == events
    assert any(e.parent_id is not None for e in events)


def test_legacy_headerless_trace_accepted(tmp_path):
    """Pre-schema files (bare {"t","l_in","l_out"} lines) still load."""
    p = str(tmp_path / "legacy.jsonl")
    with open(p, "w") as f:
        for t, li, lo in [(0.0, 8, 4), (1.5, 12, 6)]:
            f.write(json.dumps({"t": t, "l_in": li, "l_out": lo}) + "\n")
    assert load_trace(p) == [(0.0, 8, 4), (1.5, 12, 6)]


def test_future_version_rejected(tmp_path):
    p = str(tmp_path / "v99.jsonl")
    with open(p, "w") as f:
        f.write(json.dumps({"schema": TRACE_SCHEMA, "version": 99,
                            "kind": "lengths"}) + "\n")
    with pytest.raises(TraceFormatError, match="version"):
        load_trace_events(p)


# ---------------------------------------------------------------------------
# validation: every malformed line fails with path:line, never a KeyError


BAD_LINES = [
    ("not json at all", "not valid JSON"),
    ("[1, 2, 3]", "JSON object"),
    ('{"t": 1.0, "l_in": 8}', "'l_out'"),                  # missing field
    ('{"t": 1.0, "l_in": 8, "l_out": 0}', "'l_out'"),      # empty output
    ('{"t": -1.0, "l_in": 8, "l_out": 4}', "'t'"),         # negative time
    ('{"t": 1.0, "l_in": "8", "l_out": 4}', "'l_in'"),     # wrong type
    ('{"t": 1.0, "l_in": 8, "l_out": 4, "id": 5, '
     '"parent_id": 7}', "parent_id 7"),                    # dangling parent
]


@pytest.mark.parametrize("line,match", BAD_LINES)
def test_malformed_line_raises_clear_error(tmp_path, line, match):
    p = str(tmp_path / "bad.jsonl")
    with open(p, "w") as f:
        f.write(json.dumps({"schema": TRACE_SCHEMA,
                            "version": TRACE_VERSION,
                            "kind": "lengths"}) + "\n")
        f.write(json.dumps({"t": 0.0, "l_in": 8, "l_out": 4}) + "\n")
        f.write(line + "\n")
    with pytest.raises(TraceFormatError, match=match) as ei:
        load_trace_events(p)
    # the error names the file and the 1-based line it came from
    assert f"{p}:3" in str(ei.value)


def test_bad_tokens_and_duplicate_id_rejected(tmp_path):
    p = str(tmp_path / "badtok.jsonl")
    head = json.dumps({"schema": TRACE_SCHEMA, "version": TRACE_VERSION,
                       "kind": "tokens"})
    ok = json.dumps({"id": 1, "t": 0.0, "l_out": 4, "tokens": [1, 2, 3]})
    with open(p, "w") as f:
        f.write(head + "\n" + ok + "\n")
        f.write(json.dumps({"id": 2, "t": 0.5, "l_out": 4,
                            "tokens": []}) + "\n")
    with pytest.raises(TraceFormatError, match="tokens"):
        load_trace_events(p)
    with open(p, "w") as f:
        f.write(head + "\n" + ok + "\n")
        f.write(json.dumps({"id": 1, "t": 0.5, "l_out": 4,
                            "tokens": [4]}) + "\n")
    with pytest.raises(TraceFormatError, match="duplicate id 1"):
        load_trace_events(p)


def test_out_of_order_timestamps_sorted_with_warning(tmp_path):
    p = str(tmp_path / "unordered.jsonl")
    save_trace(p, [(2.0, 8, 4), (0.5, 10, 4), (1.0, 6, 4)])
    with pytest.warns(UserWarning, match="out of order"):
        evs = load_trace_events(p)
    assert [e.t for e in evs] == [0.5, 1.0, 2.0]


# ---------------------------------------------------------------------------
# bundled reference trace (DESIGN §15)


def test_reference_trace_structure():
    events = reference_trace(60, seed=1, vocab_size=400, p_followup=0.7,
                             max_turns=3)
    assert len(events) == 60
    assert [e.id for e in events] == list(range(60))        # file order
    assert all(events[i].t >= events[i - 1].t for i in range(1, 60))
    by_id = {e.id: e for e in events}
    kids = [e for e in events if e.parent_id is not None]
    assert kids, "multi-turn structure missing"
    for e in kids:
        parent = by_id[e.parent_id]
        assert parent.id < e.id and parent.t <= e.t
        # the child's prompt extends the parent's full transcript
        assert e.tokens[:len(parent.tokens)] == parent.tokens
        assert len(e.tokens) > len(parent.tokens)
    assert all(0 <= tok < 400 for e in events for tok in e.tokens)
    assert reference_trace(60, seed=1, vocab_size=400, p_followup=0.7,
                           max_turns=3) == events           # deterministic


def test_trace_prompts_materializes_both_kinds():
    tok_ev = TraceEvent(t=0.0, l_out=4, l_in=3, tokens=[5, 700, 12], id=0)
    len_ev = TraceEvent(t=1.0, l_out=6, l_in=9, id=1)
    out = trace_prompts([tok_ev, len_ev], vocab_size=256, seed=0)
    assert out[0] == ([5, 700 % 256, 12], 4)     # clamped into the vocab
    assert len(out[1][0]) == 9 and out[1][1] == 6
    assert all(0 <= t < 256 for t in out[1][0])
    assert trace_prompts([len_ev], 256, seed=0)[0][0] \
        == trace_prompts([len_ev], 256, seed=0)[0][0]


# ---------------------------------------------------------------------------
# goodput accounting through the simulator (DESIGN §15)


def test_feed_trace_goodput_sla_disabled():
    sim = _sim()
    events = reference_trace(40, seed=2, vocab_size=500)
    feed_trace(sim, events)
    res = sim.run()
    assert res.finished == 40
    assert res.sla_requests_met == 40
    assert res.request_sla_attainment == 1.0
    assert res.goodput_tokens >= res.finished      # >= 1 token per request
    assert res.goodput_tok_s > 0


def test_feed_trace_goodput_unmeetable_sla():
    serve = ServeConfig(policy="memory", b_max=64, max_new_tokens=64,
                        ttft_sla_s=1e-9)
    sim = _sim(serve)
    feed_trace(sim, reference_trace(20, seed=2, vocab_size=500))
    res = sim.run()
    assert res.finished == 20
    assert res.sla_requests_met == 0
    assert res.goodput_tokens == 0
    assert res.request_sla_attainment == 0.0
    assert res.goodput_tok_s == 0.0


def test_feed_trace_double_feed_offsets_rids():
    sim = _sim()
    feed_trace(sim, reference_trace(15, seed=3, vocab_size=500))
    feed_trace(sim, reference_trace(15, seed=4, vocab_size=500))
    rids = [r.rid for r in sim._all]
    assert len(rids) == 30 and len(set(rids)) == 30
    assert sim.run().finished == 30


# ---------------------------------------------------------------------------
# the request-level verdict itself


def test_stamp_sla_verdicts():
    def req(**kw):
        r = Request(rid=0, arrival_time=0.0, prompt_len=8)
        for k, v in kw.items():
            setattr(r, k, v)
        return r

    # TTFT 1 s; 5 tokens over (3-1)s of decode => mean TBT 500 ms
    r = req(first_token_time=1.0, finish_time=3.0, _sim_outlen=5)
    assert r.stamp_sla(0.0, 0.0)                   # both checks disabled
    assert r.stamp_sla(2.0, 600.0)                 # both met
    assert not r.stamp_sla(0.5, 600.0) and not r.ttft_ok and r.tbt_ok
    assert not r.stamp_sla(2.0, 400.0) and r.ttft_ok and not r.tbt_ok
    # single-token request: no inter-token gap, TBT check passes
    r1 = req(first_token_time=1.0, finish_time=1.0, _sim_outlen=1)
    assert r1.stamp_sla(2.0, 1e-9)
    # rejected / never-served requests can never meet the SLA
    rj = req(first_token_time=1.0, finish_time=3.0, _sim_outlen=5,
             rejected=True)
    assert not rj.stamp_sla(0.0, 0.0) and not rj.sla_met
    assert not req().stamp_sla(0.0, 0.0)
