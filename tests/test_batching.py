"""Unit + property tests for the paper's Algorithms 1 & 2."""
import math

import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.config.base import ServeConfig
from repro.config.registry import get_config
from repro.core.batching import (BatchingMemory, BatchingSLA, CombinedPolicy,
                                 StaticPolicy, bucketize, make_policy)
from repro.core.memory_model import MemoryModel, norm_cdf, norm_ppf
from repro.core.telemetry import TelemetrySnapshot

CFG = get_config("granite-3-8b")


def mem(budget_gb=64, eps=0.05):
    return MemoryModel(CFG, int(budget_gb * 2**30), eps_m=eps)


def snap(**kw):
    # tbt_samples > 0: the window is warm unless a test says otherwise
    # (BatchingSLA holds its window on a cold TBT window)
    d = dict(n_prefill_waiting=10, n_decode_running=5, mean_in=128.0,
             var_in=100.0, mean_out=128.0, var_out=400.0, tbt_ms=40.0,
             tbt_samples=16, mean_batch=64.0, arrival_rate=5.0,
             free_tokens=10_000, now=0.0)
    d.update(kw)
    return TelemetrySnapshot(**d)


# ---------------------------------------------------------------------------
# norm_ppf / norm_cdf


@given(st.floats(0.001, 0.999))
def test_ppf_cdf_inverse(q):
    assert abs(norm_cdf(norm_ppf(q)) - q) < 1e-6


# ---------------------------------------------------------------------------
# Algorithm 1


def test_alg1_adjusts_only_with_both_queues():
    m = mem()
    cfg = ServeConfig(policy="memory", b_max=4096)
    pol = BatchingMemory(cfg, m)
    # no prefill waiting: b stays at previous
    d0 = pol.step(snap(n_prefill_waiting=0))
    assert d0.max_batch == cfg.b_max  # b_prev initialized to b_max
    # both queues active: recomputed from eq. (14)
    d1 = pol.step(snap())
    expected = m.b_mem_linear(pol.L0, 256.0)
    assert d1.max_batch == min(max(expected, 5), cfg.b_max)


def test_alg1_respects_running_floor_and_bmax():
    m = mem(budget_gb=0.001)  # tiny pool -> b_mem small
    cfg = ServeConfig(policy="memory", b_max=512)
    pol = BatchingMemory(cfg, m)
    d = pol.step(snap(n_decode_running=50))
    assert d.max_batch >= 50          # never below running requests
    d2 = pol.step(snap(n_decode_running=0, n_prefill_waiting=0))
    assert d2.max_batch <= 512


@given(st.integers(1, 512), st.floats(16, 2048), st.floats(0, 1e5))
@settings(max_examples=200, deadline=None)
def test_alg1_output_always_in_bounds(n_run, mean_len, var_len):
    m = mem()
    cfg = ServeConfig(policy="memory", b_max=256, b_min=1)
    pol = BatchingMemory(cfg, m)
    d = pol.step(snap(n_decode_running=n_run, mean_in=mean_len / 2,
                      mean_out=mean_len / 2, var_in=var_len, var_out=var_len))
    assert max(min(n_run, cfg.b_max), cfg.b_min) <= d.max_batch <= max(cfg.b_max, n_run)
    assert d.max_batch <= max(cfg.b_max, n_run)


def test_alg1_monotone_in_memory():
    """More HBM -> (weakly) larger memory-safe batch."""
    cfg = ServeConfig(policy="memory", b_max=100_000)
    bs = []
    for gb in (8, 32, 128):
        pol = BatchingMemory(cfg, mem(budget_gb=gb))
        bs.append(pol.step(snap()).max_batch)
    assert bs == sorted(bs)


def test_alg1_shrinks_with_longer_sequences():
    cfg = ServeConfig(policy="memory", b_max=100_000)
    pol = BatchingMemory(cfg, mem())
    b_short = pol.step(snap(mean_in=64, mean_out=64)).max_batch
    pol2 = BatchingMemory(cfg, mem())
    b_long = pol2.step(snap(mean_in=1024, mean_out=1024)).max_batch
    assert b_long < b_short


# ---------------------------------------------------------------------------
# Algorithm 2


def slacfg(**kw):
    d = dict(policy="sla", b_min=1, b_max=256, d_sla_ms=50.0, eps_d_ms=2.0,
             alpha=16, delta=4)
    d.update(kw)
    return ServeConfig(**d)


def test_alg2_decreases_batch_when_slow():
    pol = BatchingSLA(slacfg())
    d1 = pol.step(snap(tbt_ms=80.0, mean_batch=128))
    assert d1.max_batch < 128 + 16  # window clamps toward observed batch
    # keep being slow: bound keeps dropping
    d2 = pol.step(snap(tbt_ms=80.0, mean_batch=d1.max_batch))
    assert d2.max_batch <= d1.max_batch


def test_alg2_increases_batch_when_fast():
    pol = BatchingSLA(slacfg())
    before = pol.step(snap(tbt_ms=10.0, mean_batch=32)).max_batch
    after = pol.step(snap(tbt_ms=10.0, mean_batch=before)).max_batch
    assert after >= before


def test_alg2_tightens_in_band():
    pol = BatchingSLA(slacfg())
    d = pol.step(snap(tbt_ms=50.0, mean_batch=100))
    assert abs(d.max_batch - 100) <= 16


def test_alg2_cold_start_holds_window():
    """Pre-fix, an empty TBT window (tau == 0.0) read as "headroom" every
    interval and ratcheted the window toward b_max before a single decode
    step had been measured. With zero samples the window must hold and the
    midpoint be emitted."""
    cfg = slacfg()
    pol = BatchingSLA(cfg)
    lo, hi = pol.b_low, pol.b_high
    mid = (lo + hi) // 2
    for _ in range(50):
        d = pol.step(snap(tbt_ms=0.0, tbt_samples=0, mean_batch=0.0,
                          n_decode_running=0))
        assert (pol.b_low, pol.b_high) == (lo, hi)
        assert d.max_batch == mid
    # first real sample: updates resume
    pol.step(snap(tbt_ms=200.0, tbt_samples=1, mean_batch=mid,
                  n_decode_running=0))
    assert (pol.b_low, pol.b_high) != (lo, hi)


def test_alg2_cold_start_respects_running_floor():
    pol = BatchingSLA(slacfg())
    d = pol.step(snap(tbt_ms=0.0, tbt_samples=0, n_decode_running=200))
    assert d.max_batch >= 200


@given(st.lists(st.tuples(st.floats(1, 200), st.integers(0, 256),
                          st.integers(0, 4)),
                min_size=1, max_size=50))
@settings(max_examples=100, deadline=None)
def test_alg2_invariants(seq):
    """b_min <= b_low <= b_high <= b_max holds under ANY tau/batch feedback
    sequence, including cold-window intervals."""
    cfg = slacfg()
    pol = BatchingSLA(cfg)
    for tbt, b, samples in seq:
        d = pol.step(snap(tbt_ms=tbt, tbt_samples=samples, mean_batch=b,
                          n_decode_running=0))
        assert cfg.b_min <= d.max_batch <= cfg.b_max
        assert cfg.b_min <= pol.b_low <= pol.b_high <= cfg.b_max


@given(st.floats(55, 500), st.integers(1, 8), st.integers(0, 8))
@settings(max_examples=60, deadline=None)
def test_alg2_midpoint_monotone_over_sla(tau, alpha, delta):
    """Sustained over-SLA regime with feedback-consistent b-bar: the
    midpoint never rises (from a fresh window)."""
    cfg = slacfg(alpha=alpha, delta=delta)
    pol = BatchingSLA(cfg)
    b = (pol.b_low + pol.b_high) // 2
    for _ in range(30):
        nb = pol.step(snap(tbt_ms=tau, mean_batch=b,
                           n_decode_running=0)).max_batch
        assert nb <= b
        b = nb


@given(st.floats(1, 45), st.integers(1, 8), st.integers(0, 8))
@settings(max_examples=60, deadline=None)
def test_alg2_midpoint_monotone_under_sla(tau, alpha, delta):
    """Sustained under-SLA regime: the midpoint never falls."""
    cfg = slacfg(alpha=alpha, delta=delta)
    pol = BatchingSLA(cfg)
    b = (pol.b_low + pol.b_high) // 2
    for _ in range(30):
        nb = pol.step(snap(tbt_ms=tau, mean_batch=b,
                           n_decode_running=0)).max_batch
        assert nb >= b
        b = nb


def test_alg2_converges_to_sla_batch():
    """With D(b) = 0.25*b ms and SLA 50 ms, the search should settle near
    b = 200."""
    cfg = slacfg(b_max=400, alpha=8, delta=2)
    pol = BatchingSLA(cfg)
    b = 32
    for _ in range(60):
        tbt = 0.25 * b
        b = pol.step(snap(tbt_ms=tbt, mean_batch=b, n_decode_running=0)).max_batch
    assert abs(0.25 * b - 50.0) <= 6.0, (b, 0.25 * b)


# ---------------------------------------------------------------------------
# combined + plumbing


def test_combined_is_min():
    m = mem(budget_gb=2)  # memory-limited
    cfg = ServeConfig(policy="combined", b_max=4096, d_sla_ms=50.0)
    pol = CombinedPolicy(cfg, m)
    tel = snap()
    d = pol.step(tel)
    assert d.max_batch <= max(d.b_mem, tel.n_decode_running)
    assert d.max_batch <= max(d.b_sla, tel.n_decode_running)


def test_static_policy_fixed():
    pol = StaticPolicy(ServeConfig(policy="static", b_max=77))
    for tbt in (1.0, 100.0, 500.0):
        assert pol.step(snap(tbt_ms=tbt)).max_batch == 77


def test_make_policy_dispatch():
    m = mem()
    for name, cls in [("static", StaticPolicy), ("memory", BatchingMemory),
                      ("combined", CombinedPolicy)]:
        assert isinstance(make_policy(
            ServeConfig(policy=name, d_sla_ms=50.0), m), cls)
    assert isinstance(make_policy(
        ServeConfig(policy="sla", d_sla_ms=50.0), m), BatchingSLA)
    with pytest.raises(ValueError):
        make_policy(ServeConfig(policy="nope"), m)


@given(st.integers(0, 2000))
def test_bucketize(b):
    buckets = (8, 16, 32, 64, 128)
    out = bucketize(b, buckets)
    assert out in buckets
    assert out <= b or b < 8


def test_floor_bucket_never_exceeds_decision_sim():
    """bucketize rounds UP to the smallest compiled bucket when b_t is
    below it — the graph pads, but ADMISSION must still respect the
    controller's decision. Pre-fix the sim ran a larger batch than
    BatchDecision.max_batch allowed."""
    from repro.serving.cost_model import CostModel, PROFILES
    from repro.serving.sim import LengthDist, ServingSimulator

    cfg = get_config("granite-3-8b")
    serve = ServeConfig(policy="static", b_max=2, max_new_tokens=4,
                        kv_pool_tokens=4096, batch_buckets=(4, 8))
    sim = ServingSimulator(cfg, serve,
                           CostModel(cfg, PROFILES["a100x8"]),
                           LengthDist(mean_in=8, mean_out=4, fixed=True),
                           seed=0)
    sim.add_requests(6)
    res = sim.run()
    assert res.finished == 6
    assert max(res.batch_trace) <= 2


def test_floor_bucket_never_exceeds_decision_engine():
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.models.model import build_model
    from repro.serving.engine import Engine

    cfg = get_config("granite-3-8b", "reduced")
    m = build_model(cfg, dtype=jnp.float32)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    serve = ServeConfig(policy="static", b_max=2, max_new_tokens=3,
                        kv_pool_tokens=2048, batch_buckets=(4, 8))
    eng = Engine(m, params, serve, max_context=64, buckets=(1, 2, 4, 8),
                 prefill_chunk=8)
    hs = [eng.submit(list(map(int, rng.randint(0, cfg.vocab_size, 6))),
                     max_new_tokens=3) for _ in range(5)]
    peak = 0
    while eng.step():
        peak = max(peak, len(eng.active) + len(eng.prefilling))
    assert eng.total_finished == 5
    assert peak <= 2
    assert all(len(h.output_tokens) == 3 for h in hs)


def test_chunked_prefill_budget():
    m = mem()
    cfg = ServeConfig(policy="memory", b_max=256, chunked_prefill=True)
    pol = BatchingMemory(cfg, m)
    d = pol.step(snap(n_decode_running=30))
    assert d.chunk_budget == max(d.max_batch - 30, 0)


def test_alg1_swap_pressure_shrinks_batch():
    """DESIGN §11: the swapped-out backlog holds a claim on eta — Alg 1
    must cap admission lower while it waits to swap back in, and recover
    once the backlog drains."""
    m = mem()
    cfg = ServeConfig(policy="memory", b_max=4096)

    def b_at(swapped_tokens):
        pol = BatchingMemory(cfg, m)
        return pol.step(snap(n_decode_running=1,
                             swapped_tokens=swapped_tokens)).max_batch

    b0 = b_at(0)
    b_light = b_at(50_000)
    b_heavy = b_at(500_000)
    assert b0 >= b_light >= b_heavy
    assert b0 > b_heavy                # pressure genuinely bites
    assert b_heavy >= cfg.b_min
