"""Preemption regression tests (DESIGN §11).

Pins the preemption contract across both relief valves: newest-victim
ordering in swap and recompute modes, TTFT re-attribution after recompute
(the PR-1 fix) vs TTFT preservation after swap-in, bitwise-identical
outputs across swap / recompute / no-preemption, and the ref>1 guard
(shared prefix blocks are never swapped out).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import ServeConfig
from repro.config.registry import get_config
from repro.models.model import build_model
from repro.serving.engine import Engine
from repro.serving.request import RequestState

_MODEL = {}


def setup_model():
    if not _MODEL:
        cfg = get_config("granite-3-8b", "reduced")
        m = build_model(cfg, dtype=jnp.float32)
        _MODEL["cfg"] = cfg
        _MODEL["m"] = m
        _MODEL["params"] = m.init(jax.random.PRNGKey(0))
    return _MODEL["cfg"], _MODEL["m"], _MODEL["params"]


def make_engine(m, params, *, pool=160, swap=0, preempt="auto", b_max=4,
                chunked=True, prefix=False, max_context=96):
    serve = ServeConfig(policy="static", b_max=b_max, max_new_tokens=12,
                        kv_pool_tokens=pool, block_size=16,
                        chunked_prefill=chunked, chunk_budget_tokens=16,
                        n_prefill_lanes=2, paged_kv=True,
                        prefix_cache=prefix, swap_space_blocks=swap,
                        preempt=preempt)
    return Engine(m, params, serve, max_context=max_context,
                  buckets=(1, 2, 4), prefill_chunk=8)


def submit_burst(eng, cfg, lens, max_new=12, seed=0, prompts=None):
    rng = np.random.RandomState(seed)
    hs = []
    for i, pl in enumerate(lens):
        toks = prompts[i] if prompts else \
            list(map(int, rng.randint(0, cfg.vocab_size, size=pl)))
        hs.append(eng.submit(list(toks), max_new_tokens=max_new,
                             arrival_time=0.0))
    return hs


def step_until_preemption(eng, max_steps=2000):
    """Drive the engine until the first preemption; returns the victim and
    the pre-step active rid order."""
    for _ in range(max_steps):
        before = [r.rid for r in eng.active]
        pre = eng.preemptions
        if not eng.step():
            break
        if eng.preemptions > pre:
            return before
    return None


LENS = [40, 44, 38, 46]


@pytest.mark.parametrize("swap,preempt", [(0, "auto"), (32, "swap")])
def test_newest_victim_ordering(swap, preempt):
    """The FIRST victim at the moment of pressure is the newest active
    request (vLLM preemption order) — in recompute AND swap mode."""
    cfg, m, params = setup_model()
    eng = make_engine(m, params, swap=swap, preempt=preempt)
    hs = submit_burst(eng, cfg, LENS)
    before = step_until_preemption(eng)
    assert before is not None, "workload did not trigger preemption"
    gone = [rid for rid in before if rid not in
            {r.rid for r in eng.active}
            and not any(h.rid == rid and h.state == RequestState.FINISHED
                        for h in hs)]
    # victims are taken from the tail of the active list, newest first
    assert gone == before[-len(gone):][::-1] or gone == before[-len(gone):]
    if swap:
        assert eng.swap_outs > 0
        assert all(r.rid in gone for r in eng.swapped)
    eng.run(max_steps=5000)
    assert eng.total_finished == len(LENS)


def test_ttft_reattribution_after_recompute():
    """PR-1 fix: a recompute victim's prefill_start_time resets so its
    TTFT is re-attributed from the second life's first chunk — the first
    life (decode included) must not count as prefill service."""
    cfg, m, params = setup_model()
    eng = make_engine(m, params, swap=0)
    hs = submit_burst(eng, cfg, LENS)
    assert step_until_preemption(eng) is not None
    victims = [h for h in hs if h.state == RequestState.WAITING
               and h.rid in {r.rid for r in eng.waiting}]
    assert victims
    for v in victims:
        assert v.prefill_start_time == -1.0     # re-attributed next life
        assert v.output_tokens == []            # recompute: regenerated
    t_preempt = eng._now()
    eng.run(max_steps=5000)
    assert eng.total_finished == len(LENS)
    for v in victims:
        # both timestamps re-attributed to the second life
        assert v.prefill_start_time >= t_preempt
        assert v.first_token_time >= t_preempt


def test_ttft_preserved_after_swap_in():
    """Swap-in restores the victim mid-decode: its first token already
    happened, so TTFT must NOT be re-attributed — and its generated
    tokens survive the round trip."""
    cfg, m, params = setup_model()
    eng = make_engine(m, params, swap=32, preempt="swap")
    hs = submit_burst(eng, cfg, LENS)
    assert step_until_preemption(eng) is not None
    assert eng.swapped, "expected a swapped victim"
    v = eng.swapped[0]
    ftt, pst = v.first_token_time, v.prefill_start_time
    n_out = len(v.output_tokens)
    assert ftt >= 0 and n_out > 0
    eng.run(max_steps=5000)
    assert eng.total_finished == len(LENS)
    assert v.first_token_time == ftt            # no re-attribution
    assert v.prefill_start_time == pst
    assert v.n_swaps >= 1 and v.swapped_s > 0   # latency accounted
    assert len(v.output_tokens) > n_out         # resumed, not restarted
    s = eng.summary()
    assert s["swap_latency_s_mean"] > 0
    assert s["swap_out_bytes"] > 0 and s["swap_in_bytes"] > 0
    assert s["swapped_peak"] >= 1


@pytest.mark.parametrize("chunked", [False, True])
def test_outputs_bitwise_identical_across_modes(chunked):
    """The acceptance invariant: swap, recompute, and no-preemption modes
    produce byte-identical per-request outputs (greedy decoding; swap
    restores the exact KV bytes, recompute regenerates them)."""
    cfg, m, params = setup_model()
    rng = np.random.RandomState(3)
    prompts = [list(map(int, rng.randint(0, cfg.vocab_size, size=pl)))
               for pl in LENS]

    def run(pool, swap, preempt):
        eng = make_engine(m, params, pool=pool, swap=swap, preempt=preempt,
                          chunked=chunked)
        hs = submit_burst(eng, cfg, LENS, prompts=prompts)
        eng.run(max_steps=5000)
        assert eng.total_finished == len(LENS)
        return [h.output_tokens for h in hs], eng

    out_no, _ = run(4096, 0, "auto")            # no pressure at all
    out_rc, eng_rc = run(160, 0, "auto")        # recompute preemption
    out_sw, eng_sw = run(160, 32, "swap")       # forced swap preemption
    assert eng_rc.preemptions > 0 and eng_rc.swap_outs == 0
    assert eng_sw.swap_outs > 0 and eng_sw.swap_ins == eng_sw.swap_outs
    assert out_no == out_rc == out_sw


def test_shared_prefix_blocks_never_swapped():
    """Regression: under prefix sharing, a victim whose table holds ref>1
    blocks falls back to recompute — shared blocks are never swapped out
    (the other owners' attention still reads them)."""
    cfg, m, params = setup_model()
    rng = np.random.RandomState(5)
    system = list(map(int, rng.randint(0, cfg.vocab_size, size=48)))
    prompts = [system + list(map(int, rng.randint(0, cfg.vocab_size,
                                                  size=4 + i)))
               for i in range(4)]
    eng = make_engine(m, params, pool=160, swap=32, preempt="swap",
                      prefix=True)
    hs = [eng.submit(p, max_new_tokens=24, arrival_time=0.0)
          for p in prompts]
    for _ in range(5000):
        if not eng.step():
            break
        # the invariant, checked every interval: no ledgered rid's blocks
        # were shared at swap-out time — equivalently, every block every
        # OTHER resident table references is still device-resident
        for r in eng.swapped:
            assert r.rid not in eng.blocks.tables
    assert eng.total_finished == 4
    assert eng.preemptions > 0
    # shared-prefix victims recompute; any swap that did happen was of a
    # fully private table (allocator-guaranteed: can_swap_out rejects
    # shared blocks — unit-pinned in test_kv_cache)
    for h in hs:
        assert len(h.output_tokens) == 24
