"""jaxpr trace auditor (DESIGN §13): detector unit tests plus a
representative per-family audit subset small enough for tier-1 (the CLI /
CI lint job audits every arch in the registry).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis.jaxpr_audit import (_audit_closed, audit_arch,
                                        run_jaxpr_audit)

# one family per cache layout: dense GQA, pure SSM state, RG-LRU hybrid,
# MoE routing — the layouts with distinct prefill/decode/paged graphs
SUBSET = ["granite-3-8b", "mamba2-2.7b", "recurrentgemma-9b",
          "qwen2-moe-a2.7b"]


def test_detector_flags_float64():
    from jax.experimental import enable_x64
    with enable_x64():
        closed = jax.make_jaxpr(lambda x: x * 2.0)(
            jnp.ones((2,), jnp.float64))
    fs = _audit_closed(closed, "t", "p.py")
    assert any(f.rule == "jaxpr-audit" and "float64" in f.message
               for f in fs)


def test_detector_flags_callbacks():
    def f(x):
        return jax.pure_callback(
            lambda v: v, jax.ShapeDtypeStruct(x.shape, x.dtype), x)
    closed = jax.make_jaxpr(f)(jnp.ones((2,), jnp.float32))
    fs = _audit_closed(closed, "t", "p.py")
    assert any("pure_callback" in f.message for f in fs)
    assert all(f.rule == "jaxpr-audit" for f in fs)


def test_detector_recurses_sub_jaxprs():
    def f(x):
        def body(_, v):
            return jax.pure_callback(
                lambda u: u, jax.ShapeDtypeStruct(v.shape, v.dtype), v)
        return jax.lax.fori_loop(0, 3, body, x)
    closed = jax.make_jaxpr(f)(jnp.ones((2,), jnp.float32))
    fs = _audit_closed(closed, "t", "p.py")
    assert any("pure_callback" in f.message for f in fs)


def test_clean_step_produces_no_findings():
    closed = jax.make_jaxpr(lambda x: jnp.tanh(x) + 1)(
        jnp.ones((2,), jnp.float32))
    assert _audit_closed(closed, "t", "p.py") == []


@pytest.mark.parametrize("arch", SUBSET)
def test_family_serving_steps_audit_clean(arch):
    # recompile check (2 tiny jit compiles) only on the dense family;
    # trace-only audits keep the other layouts inside the tier-1 budget
    fs = audit_arch(arch, recompile=(arch == "granite-3-8b"))
    assert fs == [], "\n".join(str(f) for f in fs)


def test_run_jaxpr_audit_subset_paths_anchor_configs():
    fs = run_jaxpr_audit(archs=["granite-3-8b"], recompile=False)
    assert fs == []
