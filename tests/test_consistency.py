"""Serving-path correctness: chunked prefill + decode must reproduce the
full forward pass for every architecture family (the invariant the paper's
scheduler relies on when it re-chunks work across intervals)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.registry import get_config
from repro.models.model import build_model

# one representative per family (full matrix runs in the nightly-style
# engine test); seamless/vlm covered in test_engine
FAMS = ["granite-3-8b", "qwen2-moe-a2.7b", "mamba2-2.7b", "recurrentgemma-9b",
        "seamless-m4t-medium", "llama-3.2-vision-90b"]


@pytest.mark.parametrize("arch", FAMS)
def test_prefill_decode_match_forward(arch):
    cfg = get_config(arch, "reduced")
    m = build_model(cfg, dtype=jnp.float32)
    params = m.init(jax.random.PRNGKey(1))
    B, T, split = 2, 24, 16
    rng = np.random.RandomState(0)
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, T)), jnp.int32)
    batch = {"tokens": toks}
    extras = {}
    if cfg.family.value == "encdec":
        extras["enc_frames"] = jnp.asarray(rng.randn(B, 16, cfg.d_model),
                                           jnp.float32)
        batch["enc_frames"] = extras["enc_frames"]
    if cfg.family.value == "vlm":
        extras["images"] = jnp.asarray(rng.randn(B, 16, cfg.d_model),
                                       jnp.float32)
        batch["images"] = extras["images"]
    full, _ = m.forward_train(params, batch, remat=False, no_drop=True)

    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    # path A: single prefill
    cache = m.init_cache(B, 64, enc_len=16)
    lgA, _ = m.prefill(params, toks, pos, cache, extras or None)
    np.testing.assert_allclose(np.asarray(lgA), np.asarray(full),
                               rtol=2e-4, atol=2e-4)

    # path B: chunked prefill + token-by-token decode
    cache = m.init_cache(B, 64, enc_len=16)
    lgB, cache = m.prefill(params, toks[:, :split], pos[:, :split], cache,
                           extras or None)
    outs = [lgB]
    for t in range(split, T):
        lg, cache = m.decode_step(params, toks[:, t],
                                  jnp.full((B,), t, jnp.int32), cache)
        outs.append(lg[:, None])
    lgB = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(lgB), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_sliding_window_ring_buffer():
    """Ring-buffer cache (window < context) must equal windowed full
    attention."""
    import dataclasses
    from repro.config.base import AttentionKind
    cfg = get_config("mistral-nemo-12b", "reduced")
    cfg = dataclasses.replace(cfg, attention=AttentionKind.SLIDING,
                              sliding_window=8)
    m = build_model(cfg, dtype=jnp.float32)
    params = m.init(jax.random.PRNGKey(2))
    B, T = 1, 20
    rng = np.random.RandomState(0)
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, T)), jnp.int32)
    full, _ = m.forward_train(params, {"tokens": toks}, remat=False)

    # ring = window + chunk - 1 = 11 << context (20); prefill in chunks of 4
    cache = m.init_cache(B, 32, prefill_chunk=4)
    assert cache["k"].shape[2] == 11
    outs = []
    pos_all = jnp.arange(T, dtype=jnp.int32)[None]
    for s in range(0, T, 4):
        lg, cache = m.prefill(params, toks[:, s:s+4], pos_all[:, s:s+4],
                              cache, None)
        outs.append(lg)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=2e-4, atol=2e-4)
