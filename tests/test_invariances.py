"""System invariants (hypothesis property tests).

1. no-drop MoE dispatch is invariant to the dispatch group size (the
   serving engine depends on this: chunk boundaries move between steps).
2. Ring-buffer decode far beyond the window equals windowed full attention
   (teacher-forced) — the long_500k serving mode's correctness basis.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.config.base import AttentionKind
from repro.config.registry import get_config
from repro.models import layers as L
from repro.models.model import build_model

CFG_MOE = get_config("qwen2-moe-a2.7b", "reduced")
_KEY = jax.random.PRNGKey(3)
_MOE_PARAMS = L.init_moe(_KEY, CFG_MOE, jnp.float32)


@given(st.sampled_from([4, 8, 16, 32, 64]),
       st.integers(min_value=0, max_value=10_000))
@settings(max_examples=20, deadline=None)
def test_moe_no_drop_group_invariance(group_size, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 24, CFG_MOE.d_model))
    y_ref, _ = L.moe_apply(_MOE_PARAMS, x, CFG_MOE, no_drop=True,
                           group_size=48)  # single group baseline
    y, _ = L.moe_apply(_MOE_PARAMS, x, CFG_MOE, no_drop=True,
                       group_size=group_size)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_ring_decode_beyond_window_matches_windowed_attention():
    cfg = get_config("mistral-nemo-12b", "reduced")
    cfg = dataclasses.replace(cfg, attention=AttentionKind.SLIDING,
                              sliding_window=8)
    m = build_model(cfg, dtype=jnp.float32)
    params = m.init(jax.random.PRNGKey(4))
    rng = np.random.RandomState(0)
    T = 28  # 3.5x the window
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (1, T)), jnp.int32)

    # reference: full-sequence windowed attention (teacher forcing)
    full, _ = m.forward_train(params, {"tokens": toks}, remat=False)

    # ring path: prefill 4 tokens, then decode one at a time to T
    cache = m.init_cache(1, 64)      # physical ring = window = 8 slots
    assert cache["k"].shape[2] == 8
    pos = jnp.arange(T, dtype=jnp.int32)[None]
    lg, cache = m.prefill(params, toks[:, :4], pos[:, :4], cache, None)
    outs = [np.asarray(lg)]
    for t in range(4, T):
        step, cache = m.decode_step(params, toks[:, t],
                                    jnp.full((1,), t, jnp.int32), cache)
        outs.append(np.asarray(step)[:, None])
    got = np.concatenate(outs, axis=1)
    np.testing.assert_allclose(got, np.asarray(full), rtol=2e-4, atol=2e-4)
