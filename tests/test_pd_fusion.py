"""PD fusion (chunked prefill) on the real engine: outputs must equal the
non-fused path, chunk budgets must be respected, and stateful families must
survive the dedicated-slot relocation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import ServeConfig
from repro.config.registry import get_config
from repro.models.model import build_model
from repro.serving.engine import Engine


@pytest.mark.parametrize("arch", ["granite-3-8b", "mamba2-2.7b",
                                  "recurrentgemma-9b"])
def test_fused_equals_nonfused(arch):
    cfg = get_config(arch, "reduced")
    m = build_model(cfg, dtype=jnp.float32)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompts = [list(map(int, rng.randint(0, cfg.vocab_size,
                                         size=rng.randint(6, 30))))
               for _ in range(4)]

    def run(chunked):
        serve = ServeConfig(policy="memory", b_max=4, max_new_tokens=5,
                            kv_pool_tokens=2048, chunked_prefill=chunked,
                            chunk_budget_tokens=8)
        eng = Engine(m, params, serve, max_context=64, buckets=(1, 2, 4),
                     prefill_chunk=8)
        hs = [eng.submit(p, max_new_tokens=5) for p in prompts]
        eng.run()
        return [h.output_tokens for h in hs], eng

    out_a, _ = run(False)
    out_b, eng = run(True)
    assert out_a == out_b
    assert eng.total_finished == 4


def test_fused_interleaves_decode_and_prefill():
    """With a long prompt arriving mid-decode, fused mode keeps decoding
    while the prompt prefills chunk by chunk (more decode steps happen
    before the late request's first token than its chunk count)."""
    cfg = get_config("granite-3-8b", "reduced")
    m = build_model(cfg, dtype=jnp.float32)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(1)
    serve = ServeConfig(policy="memory", b_max=4, max_new_tokens=16,
                        kv_pool_tokens=2048, chunked_prefill=True,
                        chunk_budget_tokens=4)
    eng = Engine(m, params, serve, max_context=128, buckets=(1, 2, 4),
                 prefill_chunk=4)
    h1 = eng.submit(list(map(int, rng.randint(0, cfg.vocab_size, 4))),
                    max_new_tokens=16)
    h2 = eng.submit(list(map(int, rng.randint(0, cfg.vocab_size, 40))),
                    max_new_tokens=4)
    eng.run()
    assert len(h1.output_tokens) == 16
    assert len(h2.output_tokens) == 4
    # the 40-token prompt needed 10 chunks of 4; decode of h1 proceeded
    # during them (fused), so h1 finished well before h2
    assert h1.finish_time < h2.finish_time
