"""Multi-lane PD-fusion prefill (DESIGN §6): lane promotion order, packer
budget enforcement, eviction with occupied lanes, and sim-vs-engine
consistency under a burst arrival trace."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import ServeConfig
from repro.config.registry import get_config
from repro.models.model import build_model
from repro.serving.cost_model import CostModel, PROFILES
from repro.serving.engine import Engine
from repro.serving.sim import LengthDist, ServingSimulator


def setup_model(arch="granite-3-8b"):
    cfg = get_config(arch, "reduced")
    m = build_model(cfg, dtype=jnp.float32)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def make_engine(m, params, *, lanes, pack="fifo", budget=16, b_max=6,
                max_new=5, pool=4096, chunk=8, max_context=64,
                policy="memory"):
    serve = ServeConfig(policy=policy, b_max=b_max, max_new_tokens=max_new,
                        kv_pool_tokens=pool, chunked_prefill=True,
                        chunk_budget_tokens=budget, n_prefill_lanes=lanes,
                        prefill_pack=pack)
    return Engine(m, params, serve, max_context=max_context,
                  buckets=(1, 2, 4, 8), prefill_chunk=chunk)


@pytest.mark.parametrize("arch", ["granite-3-8b", "mamba2-2.7b",
                                  "recurrentgemma-9b"])
def test_multilane_outputs_match_single_lane(arch):
    """Lane count and packer policy must never change the produced tokens —
    including the batched multi-row prefill graph on stateful families."""
    cfg, m, params = setup_model(arch)
    rng = np.random.RandomState(0)
    prompts = [list(map(int, rng.randint(0, cfg.vocab_size,
                                         size=rng.randint(6, 40))))
               for _ in range(6)]

    def run(lanes, pack):
        eng = make_engine(m, params, lanes=lanes, pack=pack)
        hs = [eng.submit(p, max_new_tokens=5) for p in prompts]
        eng.run()
        assert eng.total_finished == 6
        return [h.output_tokens for h in hs]

    ref = run(1, "fifo")
    for lanes, pack in [(2, "fifo"), (3, "srf"), (6, "srf")]:
        assert run(lanes, pack) == ref, (arch, lanes, pack)


def test_lane_promotion_order_concurrent_lanes():
    """With 2 lanes a short prompt arriving behind a long one prefills
    concurrently and promotes first; with 1 lane it is head-of-line blocked
    behind the long prompt."""
    cfg, m, params = setup_model()
    rng = np.random.RandomState(1)
    long_p = list(map(int, rng.randint(0, cfg.vocab_size, 48)))
    short_p = list(map(int, rng.randint(0, cfg.vocab_size, 6)))

    def first_token_order(lanes):
        # static policy: the configured chunk_budget_tokens is used as-is
        # (the memory policy would shrink it to b_t - N^d)
        eng = make_engine(m, params, lanes=lanes, budget=12, chunk=8,
                          max_new=4, max_context=96, policy="static")
        h_long = eng.submit(long_p, max_new_tokens=4)
        h_short = eng.submit(short_p, max_new_tokens=4)
        eng.run()
        assert len(h_long.output_tokens) == 4
        assert len(h_short.output_tokens) == 4
        return h_short.first_token_time < h_long.first_token_time

    assert not first_token_order(1)   # single lane: FIFO head-of-line blocks
    assert first_token_order(2)       # two lanes: short promotes first


def test_promoted_lane_lands_in_compact_decode_region():
    cfg, m, params = setup_model()
    rng = np.random.RandomState(2)
    eng = make_engine(m, params, lanes=3, budget=48)
    hs = [eng.submit(list(map(int, rng.randint(0, cfg.vocab_size, 10))),
                     max_new_tokens=6) for _ in range(3)]
    # step until all three promoted
    for _ in range(200):
        if not eng.step():
            break
        if len(eng.active) == 3:
            break
    assert sorted(r.slot for r in eng.active) == \
        list(range(len(eng.active)))
    assert all(r.lane == -1 for r in eng.active)
    assert all(l is None for l in eng.lanes)
    eng.run()
    assert eng.total_finished == 3


def test_packer_respects_chunk_budget():
    cfg, m, params = setup_model()
    rng = np.random.RandomState(3)
    budget = 16
    eng = make_engine(m, params, lanes=4, budget=budget, chunk=8, b_max=8,
                      policy="static")
    prompts = [list(map(int, rng.randint(0, cfg.vocab_size,
                                         size=rng.randint(10, 40))))
               for _ in range(8)]
    for p in prompts:
        eng.submit(p, max_new_tokens=3)
    eng.run()
    assert eng.total_finished == 8
    assert eng.prefill_tokens_trace, "no fused prefill interval recorded"
    assert max(eng.prefill_tokens_trace) <= budget
    # no preemption in this run: every prompt token is prefilled exactly once
    assert eng.preemptions == 0
    assert sum(eng.prefill_tokens_trace) == sum(len(p) for p in prompts)


def test_eviction_with_occupied_lanes():
    """Preemption compacts the decode region while lanes hold prefilling
    requests in the spare rows; everything must still complete."""
    cfg, m, params = setup_model()
    rng = np.random.RandomState(4)
    # tiny pool: 6 requests growing to ~50 tokens against 192 pool tokens
    eng = make_engine(m, params, lanes=2, budget=32, b_max=8, max_new=40,
                      pool=192)
    hs = [eng.submit(list(map(int, rng.randint(0, cfg.vocab_size, 10))),
                     max_new_tokens=40) for _ in range(6)]
    eng.run(max_steps=5000)
    assert eng.total_finished == 6
    assert eng.preemptions > 0
    assert all(len(h.output_tokens) > 0 for h in hs)


def test_lane_telemetry_and_summary():
    cfg, m, params = setup_model()
    rng = np.random.RandomState(5)
    eng = make_engine(m, params, lanes=3, budget=24, b_max=6)
    for _ in range(6):
        eng.submit(list(map(int, rng.randint(0, cfg.vocab_size, 20))),
                   max_new_tokens=4)
    eng.run()
    s = eng.summary()
    assert 0.0 < s["prefill_lane_occupancy"] <= 1.0
    assert s["prefill_tokens"] == 6 * 20
    assert s["ttft_prefill_s_mean"] > 0.0
    # per-lane attribution recorded for every lane that saw work
    assert sum(eng.tel.lane_tokens.values()) == 6 * 20
    assert set(eng.tel.lane_tokens) <= {0, 1, 2}


def test_fifo_budget_is_arrival_order_no_lane_starvation():
    """With a tight budget, FIFO must feed the OLDEST occupied lane first —
    lane-index order would let lane 0, refilled with ever-newer arrivals,
    starve an older request parked in lane 1."""
    from repro.core.lanes import pack_chunks
    from repro.serving.request import Request

    old = Request(rid=1, arrival_time=0.0, prompt_len=100)
    new = Request(rid=7, arrival_time=5.0, prompt_len=100)
    # newer request holds the LOWER lane index
    plan = pack_chunks("fifo", [new, old], budget_tokens=8, chunk_cap=8)
    assert plan == [(1, old, 8)]
    # srf unaffected: shortest remaining first regardless of age
    old.prefill_pos = 0
    new.prefill_pos = 96
    plan = pack_chunks("srf", [new, old], budget_tokens=8, chunk_cap=8)
    assert plan[0][1] is new


def burst_sim(n_lanes, *, n=300, seed=0):
    cfg = get_config("granite-3-8b")
    cost = CostModel(cfg, PROFILES["a100x8"])
    lengths = LengthDist(mean_in=128, mean_out=64, fixed=True)
    serve = ServeConfig(policy="memory", b_max=512, max_new_tokens=64,
                        chunked_prefill=True, chunk_budget_tokens=256,
                        n_prefill_lanes=n_lanes, prefill_pack="srf")
    sim = ServingSimulator(cfg, serve, cost, lengths, seed=seed,
                           prefill_chunk=64)
    sim.add_requests(n, arrival_rate=200.0)   # burst-style arrivals
    return sim.run()


def test_sim_multilane_improves_burst_ttft_and_occupancy():
    """The acceptance curve: >= 2 lanes must raise decode-batch occupancy
    and cut mean TTFT vs the single-lane baseline, with identical tokens."""
    r1 = burst_sim(1)
    r4 = burst_sim(4)
    assert r1.finished == r4.finished == 300
    assert r4.total_tokens == r1.total_tokens
    assert r4.mean_batch > r1.mean_batch
    assert r4.ttft_mean_s < r1.ttft_mean_s
    assert r4.duration_s <= r1.duration_s


def test_sim_vs_engine_multilane_consistency_burst():
    """Sim and engine must agree on the direction and rough magnitude of
    the multi-lane effect under a burst trace: more lanes -> fewer
    scheduling intervals and higher decode-batch occupancy, with identical
    tokens. (The sim is the engine's discrete-event twin — DESIGN §7.)"""
    cfg, m, params = setup_model()
    rng = np.random.RandomState(7)
    prompts = [list(map(int, rng.randint(0, cfg.vocab_size, 24)))
               for _ in range(8)]

    def engine_run(lanes):
        eng = make_engine(m, params, lanes=lanes, budget=32, chunk=8,
                          b_max=8, max_new=8, max_context=96)
        hs = [eng.submit(p, max_new_tokens=8) for p in prompts]
        steps = eng.run()
        assert eng.total_finished == 8
        return [h.output_tokens for h in hs], steps, eng.summary()

    out1, steps1, sum1 = engine_run(1)
    out4, steps4, sum4 = engine_run(4)
    assert out1 == out4                       # identical tokens
    assert steps4 <= steps1                   # fewer scheduling intervals
    assert sum4["mean_batch"] >= sum1["mean_batch"]

    # the sim twin shows the same ordering on the equivalent workload
    def sim_run(lanes):
        cost = CostModel(get_config("granite-3-8b"), PROFILES["a100x8"])
        lengths = LengthDist(mean_in=24, mean_out=8, fixed=True)
        serve = ServeConfig(policy="memory", b_max=8, max_new_tokens=8,
                            chunked_prefill=True, chunk_budget_tokens=32,
                            n_prefill_lanes=lanes)
        sim = ServingSimulator(get_config("granite-3-8b"), serve, cost,
                               lengths, seed=0, prefill_chunk=8)
        sim.add_requests(8)
        return sim.run()

    s1, s4 = sim_run(1), sim_run(4)
    assert s1.finished == s4.finished == 8
    assert len(s4.batch_trace) <= len(s1.batch_trace)
    assert s4.mean_batch >= s1.mean_batch
