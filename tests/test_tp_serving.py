"""Mesh-sharded serving tests (DESIGN §12).

A subprocess gets 4 forced host devices and runs the engine on a
(data=2, model=2) test mesh: paged and contiguous layouts, swap on and
off, with three families of assertions —

* bitwise-identical output tokens vs the single-device engine (TP must
  not change what gets decoded);
* chip-aware capacity: the pool token capacity and Alg-1's free-token
  signal scale with the model-axis size at fixed per-chip pool, and a
  mesh engine at per-chip pool P behaves counter-for-counter like a
  single-device engine at pool m·P;
* engine-vs-sim differential parity under a mesh (the sim mirrors the
  per-chip budget), and the shard_map paged Pallas kernel is bitwise
  identical to the single-device kernel.
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parent.parent / "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax, jax.numpy as jnp
    import numpy as np

    from repro.config.base import ServeConfig
    from repro.config.registry import get_config
    from repro.core.telemetry import Telemetry
    from repro.models.model import build_model
    from repro.serving.cost_model import CostModel, PROFILES
    from repro.serving.engine import Engine
    from repro.serving.request import Request
    from repro.serving.sim import LengthDist, ServingSimulator

    MAX_CONTEXT = 96
    cfg = get_config("granite-3-8b", "reduced")
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    cost = CostModel(cfg, PROFILES["a100x8"])
    out = {}

    def prompts_of(lens, seed):
        rng = np.random.RandomState(seed)
        return [list(map(int, rng.randint(0, cfg.vocab_size, size=pl)))
                for pl in lens]

    def run_engine(serve, lens, max_new, seed=0):
        eng = Engine(model, params, serve, max_context=MAX_CONTEXT,
                     buckets=(1, 2, 4), prefill_chunk=8, cost=cost)
        hs = [eng.submit(p, max_new_tokens=max_new, arrival_time=0.0)
              for p in prompts_of(lens, seed)]
        eng.run(max_steps=20_000)
        return eng, [h.output_tokens for h in hs]

    def serve_cfg(mesh=(), paged=True, pool=256, swap=0, preempt="auto",
                  policy="static", chunked=True):
        return ServeConfig(policy=policy, b_max=4, max_new_tokens=8,
                           kv_pool_tokens=pool, block_size=16,
                           chunked_prefill=chunked, chunk_budget_tokens=24,
                           n_prefill_lanes=2, paged_kv=paged,
                           swap_space_blocks=swap, preempt=preempt,
                           mesh_shape=mesh)

    LENS = [28, 34, 22, 30, 26]

    # 1) paged: mesh vs single-device — identical tokens, scaled capacity,
    #    zero row copies, pool physically sharded over "model"
    e1, o1 = run_engine(serve_cfg(), LENS, 8)
    e2, o2 = run_engine(serve_cfg(mesh=(2, 2)), LENS, 8)
    out["paged"] = {
        "outputs_identical": o1 == o2,
        "capacity_single": e1.mem.eta, "capacity_mesh": e2.mem.eta,
        "model_shards": e2.model_shards,
        "copy_rows_mesh": e2.copy_rows,
        "pool_spec": str(e2.cache["k"].sharding.spec),
        "finished": [e1.total_finished, e2.total_finished],
    }

    # 2) contiguous fallback cache on the same mesh — identical tokens
    e3, o3 = run_engine(serve_cfg(paged=False), LENS, 8)
    e4, o4 = run_engine(serve_cfg(paged=False, mesh=(2, 2)), LENS, 8)
    out["contiguous"] = {
        "outputs_identical": o3 == o4 == o1,
        "cache_spec": str(e4.cache["k"].sharding.spec),
    }

    # 3) chip-aware accounting: mesh engine at per-chip pool P must match a
    #    single-device engine at pool m*P counter for counter (same eta ->
    #    same BlockManager decisions), under swap pressure, forced swaps
    tight = serve_cfg(mesh=(1, 2), pool=80, swap=24, preempt="swap")
    wide = serve_cfg(pool=160, swap=24, preempt="swap")
    e5, o5 = run_engine(tight, [40, 44, 38, 46], 12, seed=2)
    e6, o6 = run_engine(wide, [40, 44, 38, 46], 12, seed=2)
    out["perchip"] = {
        "eta": [e5.mem.eta, e6.mem.eta],
        "outputs_identical": o5 == o6,
        "swap_outs": [e5.swap_outs, e6.swap_outs],
        "swap_ins": [e5.swap_ins, e6.swap_ins],
        "preemptions": [e5.preemptions, e6.preemptions],
        "oom_events": [e5.oom_events, e6.oom_events],
        "admitted": [e5.admitted_total, e6.admitted_total],
    }

    # 4) engine-vs-sim differential parity under a mesh, swap on and off:
    #    the sim twin scales the same per-chip pool by the same shard rule
    def diff_pair(serve, lens, max_new, seed):
        eng, _ = run_engine(serve, lens, max_new, seed=seed)
        sim = ServingSimulator(cfg, serve, cost,
                               LengthDist(mean_in=float(np.mean(lens)),
                                          mean_out=float(max_new)),
                               seed=0, prefill_chunk=8,
                               max_context=MAX_CONTEXT)
        sim.tel = Telemetry()
        for i, pl in enumerate(lens):
            sim.waiting.append(Request(
                rid=i, arrival_time=0.0, prompt_len=pl,
                max_new_tokens=min(max_new, MAX_CONTEXT - pl - 1)))
        sim._all.extend(sim.waiting)
        res = sim.run(max_steps=20_000)
        return {
            "eta": [eng.mem.eta, sim.mem.eta],
            "admitted": [eng.admitted_total, res.admitted],
            "preemptions": [eng.preemptions, res.preemptions],
            "oom_events": [eng.oom_events, res.oom_events],
            "rejected": [eng.rejected, res.rejected],
            "swap_outs": [eng.swap_outs, res.swap_outs],
            "swap_ins": [eng.swap_ins, res.swap_ins],
            "drained": not (eng.waiting or eng.active or eng.prefilling
                            or eng.swapped or sim.waiting or sim.running
                            or sim.pending_prefill or sim.swapped),
        }

    out["diff_noswap"] = diff_pair(
        serve_cfg(mesh=(2, 2), pool=96, policy="memory"),
        [40, 44, 38, 46], 12, seed=1)
    out["diff_swap"] = diff_pair(
        serve_cfg(mesh=(2, 2), pool=80, swap=24, preempt="swap"),
        [40, 44, 38, 46], 12, seed=2)

    # 5) shard_map paged Pallas kernel (interpret): bitwise vs the
    #    single-device kernel, close to the jnp oracle
    from jax.experimental.shard_map import shard_map
    from repro.kernels.decode_attention import paged_decode_attention_kernel
    from repro.kernels.ops import paged_decode_attention_tp
    from repro.kernels.ref import paged_decode_attention_ref
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh((2, 2))
    B, H, KV, hd, NB, bs = 3, 4, 2, 32, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    kpool = jax.random.normal(ks[0], (NB, bs, KV, hd), jnp.float32)
    vpool = jax.random.normal(ks[1], (NB, bs, KV, hd), jnp.float32)
    q = jax.random.normal(ks[2], (B, H, hd), jnp.float32)
    kpos = jnp.tile(jnp.arange(bs)[None], (NB, 1))
    tables = jnp.array([[0, 1, -1, -1], [2, 3, 4, -1], [5, -1, -1, -1]],
                       jnp.int32)
    qpos = jnp.array([20, 40, 10], jnp.int32)
    tp = paged_decode_attention_tp(q, kpool, vpool, qpos, kpos, tables,
                                   mesh=mesh)
    single = paged_decode_attention_kernel(q, kpool, vpool, qpos, kpos,
                                           tables, interpret=True)
    ref = paged_decode_attention_ref(q, kpool, vpool, qpos, kpos, tables)
    out["kernel"] = {
        "tp_bitwise_vs_single": bool(jnp.all(tp == single)),
        "tp_vs_ref_maxdiff": float(jnp.max(jnp.abs(tp - ref))),
    }

    print("RESULT" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def tp_results():
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


def test_paged_mesh_outputs_bitwise_identical(tp_results):
    r = tp_results["paged"]
    assert r["outputs_identical"]
    assert r["finished"][0] == r["finished"][1] == 5


def test_paged_pool_sharded_and_capacity_scales(tp_results):
    r = tp_results["paged"]
    assert r["model_shards"] == 2
    assert r["capacity_mesh"] == 2 * r["capacity_single"]
    assert "model" in r["pool_spec"]      # K/V pools physically sharded
    assert r["copy_rows_mesh"] == 0       # paged O(1) moves survive TP


def test_contiguous_mesh_outputs_bitwise_identical(tp_results):
    r = tp_results["contiguous"]
    assert r["outputs_identical"]
    assert "model" in r["cache_spec"]


def test_perchip_pool_equals_scaled_single_device(tp_results):
    """A (model=2) engine at per-chip pool P is counter-for-counter the
    single-device engine at pool 2P — admission, watermark, preemption,
    and swap all see the same sharded capacity (DESIGN §12)."""
    r = tp_results["perchip"]
    assert r["eta"][0] == r["eta"][1]
    assert r["swap_outs"][0] > 0          # the regime actually triggered
    for key in ("outputs_identical",):
        assert r[key]
    for key in ("swap_outs", "swap_ins", "preemptions", "oom_events",
                "admitted"):
        assert r[key][0] == r[key][1], (key, r)


@pytest.mark.parametrize("scenario", ["diff_noswap", "diff_swap"])
def test_differential_parity_under_mesh(tp_results, scenario):
    """Engine-vs-sim differential parity holds under a (2, 2) mesh: the
    sim mirrors the per-chip budget via the same shard rule."""
    r = tp_results[scenario]
    assert r["drained"]
    for key in ("eta", "admitted", "preemptions", "oom_events", "rejected",
                "swap_outs", "swap_ins"):
        assert r[key][0] == r[key][1], (key, r)
    if scenario == "diff_swap":
        assert r["swap_outs"][0] > 0


def test_shard_map_paged_kernel_bitwise(tp_results):
    r = tp_results["kernel"]
    assert r["tp_bitwise_vs_single"]
    assert r["tp_vs_ref_maxdiff"] < 1e-5
