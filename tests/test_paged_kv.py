"""Physically paged KV cache (DESIGN §9).

Covers the tentpole — paged-vs-contiguous equivalence at the model and
engine level, the paged flash-decode Pallas kernel, zero-copy lifecycle —
and the allocator-drift regression family: state-only (SSM) block leak,
failed-grow preemption, and engine/sim admission parity under
batch_buckets + the free-block watermark.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import ServeConfig
from repro.config.registry import get_config
from repro.models.model import build_model
from repro.serving.engine import Engine
from repro.serving.kv_cache import BlockManager

ARCHS = ["granite-3-8b", "mamba2-2.7b", "recurrentgemma-9b"]


def setup_model(arch):
    cfg = get_config(arch, "reduced")
    m = build_model(cfg, dtype=jnp.float32)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


# ---------------------------------------------------------------------------
# paged flash-decode kernel vs gather-then-attend oracle


def test_paged_kernel_matches_ref():
    from repro.kernels import ops

    rng = np.random.RandomState(0)
    B, H, KV, hd, NB, bs, MB = 3, 4, 2, 16, 10, 8, 4
    q = jnp.asarray(rng.randn(B, H, hd), jnp.float32)
    kp = jnp.asarray(rng.randn(NB, bs, KV, hd), jnp.float32)
    vp = jnp.asarray(rng.randn(NB, bs, KV, hd), jnp.float32)
    # non-contiguous, non-monotone physical blocks per request
    owned = [[2, 5, 7], [1], [9, 0]]
    tables = np.full((B, MB), -1, np.int32)
    for b, tbl in enumerate(owned):
        tables[b, :len(tbl)] = tbl
    q_pos = jnp.asarray([20, 5, 11], jnp.int32)
    kpos = np.full((NB, bs), -1, np.int32)
    for b, tbl in enumerate(owned):
        for j, pb in enumerate(tbl):
            for o in range(bs):
                p = j * bs + o
                if p <= int(q_pos[b]):
                    kpos[pb, o] = p
    kpos[3] = 2  # stale positions in an UNOWNED block must stay invisible
    tables, kpos = jnp.asarray(tables), jnp.asarray(kpos)
    for window in (0, 6):
        ref = ops.paged_decode_attention(q, kp, vp, q_pos, kpos, tables,
                                         window=window, use_kernel=False)
        ker = ops.paged_decode_attention(q, kp, vp, q_pos, kpos, tables,
                                         window=window, use_kernel=True)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(ker),
                                   rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# model-level equivalence: identical decode logits and final cache contents


@pytest.mark.parametrize("arch", ARCHS)
def test_model_paged_equals_contiguous(arch):
    cfg, m, params = setup_model(arch)
    rng = np.random.RandomState(0)
    max_ctx, bs, n_new = 64, 16, 6
    lens = [12, 9]
    B = len(lens)
    T = max(lens)
    toks = np.zeros((B, T), np.int32)
    pos = np.full((B, T), -1, np.int32)
    for i, l in enumerate(lens):
        toks[i, :l] = rng.randint(0, cfg.vocab_size, size=l)
        pos[i, :l] = np.arange(l)
    toks, pos = jnp.asarray(toks), jnp.asarray(pos)

    cache_c = m.init_cache(B, max_ctx, enc_len=16, prefill_chunk=T)
    lg_c, cache_c = m.prefill(params, toks, pos, cache_c, None)

    bm = BlockManager(total_tokens=256, block_size=bs)
    MB = -(-max_ctx // bs)
    for i, l in enumerate(lens):
        assert bm.allocate(i, 0, l + n_new + 1)
    tbl = np.full((B, MB), -1, np.int32)
    for i in range(B):
        tbl[i, :len(bm.tables[i])] = bm.tables[i]
    tables = jnp.asarray(tbl)
    cache_p = m.init_paged_cache(B, bm.num_blocks, bs, enc_len=16)
    lg_p, cache_p = m.prefill_paged(params, toks, pos, tables, cache_p, None)
    np.testing.assert_array_equal(np.asarray(lg_c), np.asarray(lg_p))

    outs = [int(jnp.argmax(lg_c[i, lens[i] - 1])) for i in range(B)]
    cur = list(lens)
    for _ in range(n_new):
        tt = jnp.asarray(outs, jnp.int32)
        sl = jnp.asarray(cur, jnp.int32)
        lg_c, cache_c = m.decode_step(params, tt, sl, cache_c)
        lg_p, cache_p = m.decode_step_paged(params, tt, sl, tables, cache_p)
        np.testing.assert_array_equal(np.asarray(lg_c), np.asarray(lg_p))
        outs = [int(jnp.argmax(lg_c[i])) for i in range(B)]
        cur = [c + 1 for c in cur]

    # final cache contents: every written K/V slot must be identical
    if "k" in cache_c:
        from repro.models.layers import paged_view
        L = cache_c["k"].shape[0]
        for lay in range(L):
            kview, vview, kpos = paged_view(
                cache_p["k"][lay], cache_p["v"][lay], cache_p["pos"], tables)
            for i, c in enumerate(cur):
                np.testing.assert_array_equal(
                    np.asarray(cache_c["k"][lay, i, :c]),
                    np.asarray(kview[i, :c]))
                np.testing.assert_array_equal(
                    np.asarray(cache_c["v"][lay, i, :c]),
                    np.asarray(vview[i, :c]))
                np.testing.assert_array_equal(
                    np.asarray(cache_c["pos"][i, :c]),
                    np.asarray(kpos[i, :c]))
    for key in ("conv", "ssm", "rec"):
        if key in cache_c:
            np.testing.assert_array_equal(np.asarray(cache_c[key]),
                                          np.asarray(cache_p[key]))


# ---------------------------------------------------------------------------
# engine-level equivalence + zero-copy lifecycle


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("chunked", [False, True])
def test_engine_paged_equals_contiguous(arch, chunked):
    cfg, m, params = setup_model(arch)
    rng = np.random.RandomState(0)
    prompts = [list(map(int, rng.randint(0, cfg.vocab_size,
                                         size=rng.randint(6, 30))))
               for _ in range(4)]

    def run(paged):
        serve = ServeConfig(policy="memory", b_max=4, max_new_tokens=5,
                            kv_pool_tokens=2048, chunked_prefill=chunked,
                            chunk_budget_tokens=8, n_prefill_lanes=2,
                            paged_kv=paged)
        eng = Engine(m, params, serve, max_context=64, buckets=(1, 2, 4),
                     prefill_chunk=8)
        hs = [eng.submit(p, max_new_tokens=5) for p in prompts]
        eng.run()
        assert eng.total_finished == 4
        return [h.output_tokens for h in hs], eng

    out_c, eng_c = run(False)
    out_p, eng_p = run(True)
    assert out_c == out_p
    # the tentpole invariant: paged lifecycle performs ZERO row copies
    assert eng_p.copy_rows == 0
    assert eng_p.copy_bytes == 0
    if chunked:
        # contiguous lane promotion copies a full row per promoted request
        assert eng_c.copy_rows > 0
        assert eng_c.copy_bytes > 0


def test_paged_eviction_zero_copies():
    """Preemption storms under a tight pool stay O(1) in paged mode: blocks
    and the pinned state row are released, no cache_copy_row compaction."""
    cfg, m, params = setup_model("granite-3-8b")
    rng = np.random.RandomState(4)
    serve = ServeConfig(policy="static", b_max=8, max_new_tokens=40,
                        kv_pool_tokens=192, block_size=16, paged_kv=True,
                        chunked_prefill=True, chunk_budget_tokens=32,
                        n_prefill_lanes=2)
    eng = Engine(m, params, serve, max_context=64, buckets=(1, 2, 4, 8),
                 prefill_chunk=8)
    hs = [eng.submit(list(map(int, rng.randint(0, cfg.vocab_size, 10))),
                     max_new_tokens=40) for _ in range(6)]
    eng.run(max_steps=5000)
    assert eng.total_finished == 6
    assert eng.preemptions > 0
    assert eng.copy_rows == 0
    assert all(len(h.output_tokens) > 0 for h in hs)
    # allocator fully restored, no leaked blocks or slots
    assert eng.blocks.free_blocks == eng.blocks.num_blocks
    assert sorted(eng._free_slots) == list(range(eng.n_slots))


def test_paged_multimodal_roundtrip():
    """Cross-KV state rides the pinned slot row; extras-carrying first
    chunks run through the paged single-row path."""
    cfg, m, params = setup_model("llama-3.2-vision-90b")
    rng = np.random.RandomState(4)
    extras = {"images": jnp.asarray(rng.randn(1, 16, cfg.d_model),
                                    jnp.float32)}
    prompt = list(map(int, rng.randint(0, cfg.vocab_size, size=6)))

    def run(paged):
        serve = ServeConfig(policy="memory", b_max=2, max_new_tokens=5,
                            kv_pool_tokens=1024, chunked_prefill=True,
                            chunk_budget_tokens=8, paged_kv=paged)
        eng = Engine(m, params, serve, max_context=64, buckets=(1, 2),
                     prefill_chunk=8, enc_len=16)
        h = eng.submit(prompt, max_new_tokens=5, extras=extras)
        eng.run()
        return h.output_tokens

    assert run(True) == run(False)


# ---------------------------------------------------------------------------
# allocator-drift regressions


def test_ssm_long_decode_no_spurious_preemptions():
    """State-only families must not leak a block per decode step: a long
    decode against a small pool finishes with zero preemptions and the
    allocator's footprint stays at admission size (one block/request)."""
    cfg, m, params = setup_model("mamba2-2.7b")
    assert cfg.kv_bytes_per_token() == 0
    rng = np.random.RandomState(0)
    serve = ServeConfig(policy="static", b_max=4, max_new_tokens=56,
                        kv_pool_tokens=64, block_size=16)  # only 4 blocks
    eng = Engine(m, params, serve, max_context=64, buckets=(1, 2, 4),
                 prefill_chunk=8)
    hs = [eng.submit(list(map(int, rng.randint(0, cfg.vocab_size, 6))),
                     max_new_tokens=56) for _ in range(3)]
    eng.run(max_steps=2000)
    assert eng.total_finished == 3
    assert all(len(h.output_tokens) == 56 for h in hs)
    # pre-fix: free_tokens drained ~1 block per request per block_size
    # steps, triggering spurious preemptions long before completion
    assert eng.preemptions == 0
    assert eng.blocks.free_blocks == eng.blocks.num_blocks


def test_sim_ssm_long_decode_no_drift():
    from repro.serving.cost_model import CostModel, PROFILES
    from repro.serving.sim import LengthDist, ServingSimulator

    cfg = get_config("mamba2-2.7b")
    cost = CostModel(cfg, PROFILES["a100x8"])
    lengths = LengthDist(mean_in=64, mean_out=256, fixed=True)
    serve = ServeConfig(policy="static", b_max=8, max_new_tokens=256,
                        kv_pool_tokens=0, block_size=16)
    sim = ServingSimulator(cfg, serve, cost, lengths, seed=0)
    sim.add_requests(8)
    res = sim.run()
    assert res.finished == 8
    assert res.preemptions == 0
    assert res.oom_events == 0
    assert sim.blocks.free_blocks == sim.blocks.num_blocks


@pytest.mark.parametrize("paged", [False, True])
def test_failed_grow_preempts_instead_of_drifting(paged):
    """A decode-step grow that fails must preempt the request (recompute),
    never emit tokens without backing blocks; the allocator invariant
    (owned + free == total) and per-request coverage must hold."""
    cfg, m, params = setup_model("granite-3-8b")
    rng = np.random.RandomState(1)
    serve = ServeConfig(policy="static", b_max=2, max_new_tokens=30,
                        kv_pool_tokens=512, block_size=16, paged_kv=paged)
    eng = Engine(m, params, serve, max_context=64, buckets=(1, 2),
                 prefill_chunk=8)
    hs = [eng.submit(list(map(int, rng.randint(0, cfg.vocab_size, 10))),
                     max_new_tokens=30) for _ in range(2)]
    for _ in range(3):
        eng.step()
    assert len(eng.active) == 2
    # exhaust the pool behind the scheduler's back and disable the
    # softer preempt-ahead check so the grow itself must fail
    eng.blocks.allocate(9999, 0, eng.blocks.free_tokens)
    eng._preempt_if_needed = lambda: None
    for _ in range(40):
        if eng.preemptions:
            break
        eng.step()
    assert eng.preemptions > 0
    bm = eng.blocks
    owned = sum(len(t) for t in bm.tables.values())
    assert owned + bm.free_blocks == bm.num_blocks
    # every still-active request has full block coverage for its context
    for r in eng.active:
        assert len(bm.tables[r.rid]) * bm.block_size >= r.context_len
    # evicted requests emitted nothing unbacked: outputs were cleared
    evicted = [h for h in hs if h in eng.waiting]
    assert all(h.output_tokens == [] for h in evicted)


def test_engine_admission_bucketized_matches_sim():
    """DESIGN §7 parity: with batch_buckets set, the engine bucketizes the
    policy cap exactly like the simulator — 7 ready requests against
    buckets (1,2,4) admit at most 4 concurrently in both."""
    from repro.core.batching import bucketize
    from repro.serving.cost_model import CostModel, PROFILES
    from repro.serving.sim import LengthDist, ServingSimulator

    cfg, m, params = setup_model("granite-3-8b")
    rng = np.random.RandomState(2)
    buckets = (1, 2, 4)
    serve = ServeConfig(policy="static", b_max=8, max_new_tokens=6,
                        kv_pool_tokens=4096, batch_buckets=buckets)
    cap = bucketize(serve.b_max, buckets)
    eng = Engine(m, params, serve, max_context=64, buckets=(1, 2, 4, 8),
                 prefill_chunk=8)
    hs = [eng.submit(list(map(int, rng.randint(0, cfg.vocab_size, 6))),
                     max_new_tokens=6) for _ in range(7)]
    peak = 0
    while eng.step():
        peak = max(peak, len(eng.active) + len(eng.prefilling))
    assert eng.total_finished == 7
    assert peak == cap
    assert all(len(h.output_tokens) == 6 for h in hs)

    sim_cfg = get_config("granite-3-8b")
    cost = CostModel(sim_cfg, PROFILES["a100x8"])
    lengths = LengthDist(mean_in=6, mean_out=6, fixed=True)
    sim = ServingSimulator(sim_cfg, serve, cost, lengths, seed=0)
    sim.add_requests(7)
    res = sim.run()
    assert res.finished == 7
    assert max(res.batch_trace) == cap


def test_pallas_paged_decode_matches_jnp(tmp_path):
    """The paged decode path through the Pallas kernel (interpret mode on
    CPU) must match the pure-jnp gathered view — subprocess per backend,
    mirroring tests/test_pallas_integration.py."""
    import os
    import subprocess
    import sys
    import textwrap
    from pathlib import Path

    src = str(Path(__file__).resolve().parent.parent / "src")
    script = textwrap.dedent("""
        import os
        os.environ["REPRO_USE_PALLAS"] = os.environ["WANT_PALLAS"]
        import jax, jax.numpy as jnp, numpy as np
        from repro.config.registry import get_config
        from repro.models.model import build_model
        from repro.serving.kv_cache import BlockManager

        cfg = get_config("granite-3-8b", "reduced")
        m = build_model(cfg, dtype=jnp.float32)
        params = m.init(jax.random.PRNGKey(0))
        rng = np.random.RandomState(0)
        bm = BlockManager(total_tokens=128, block_size=16)
        bm.allocate(0, 0, 20); bm.allocate(1, 0, 20)
        tbl = np.full((2, 2), -1, np.int32)
        for i in range(2):
            tbl[i, :len(bm.tables[i])] = bm.tables[i]
        tables = jnp.asarray(tbl)
        toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 12)), jnp.int32)
        pos = jnp.broadcast_to(jnp.arange(12, dtype=jnp.int32)[None], (2, 12))
        cache = m.init_paged_cache(2, bm.num_blocks, 16)
        lg, cache = m.prefill_paged(params, toks, pos, tables, cache, None)
        outs = [int(jnp.argmax(lg[0, -1]))]
        vals = []
        for t in range(12, 18):
            lg, cache = m.decode_step_paged(
                params, jnp.full((2,), outs[-1], jnp.int32),
                jnp.full((2,), t, jnp.int32), tables, cache)
            outs.append(int(jnp.argmax(lg[0])))
            vals.append(np.asarray(lg))
        np.save(os.environ["OUT_NPY"], np.stack(vals))
    """)

    def run_variant(want, out):
        env = dict(os.environ, PYTHONPATH=src, WANT_PALLAS=want, OUT_NPY=out)
        proc = subprocess.run([sys.executable, "-c", script], env=env,
                              capture_output=True, text=True, timeout=540)
        assert proc.returncode == 0, proc.stderr[-2000:]

    a, b = str(tmp_path / "a.npy"), str(tmp_path / "b.npy")
    run_variant("0", a)
    run_variant("1", b)
    np.testing.assert_allclose(np.load(a), np.load(b), rtol=2e-4, atol=2e-4)


def test_engine_watermark_counts_oom_events():
    """The vLLM-style 1% free-block floor refuses admissions that would
    empty the pool, counting oom_events (previously engine-only silent).
    A request the pool can NEVER hold is rejected outright instead of
    wedging the queue in a no-op busy-spin."""
    from repro.serving.request import RequestState

    cfg, m, params = setup_model("granite-3-8b")
    rng = np.random.RandomState(3)
    serve = ServeConfig(policy="static", b_max=2, max_new_tokens=4,
                        kv_pool_tokens=32, block_size=16)  # 2 blocks
    eng = Engine(m, params, serve, max_context=64, buckets=(1, 2),
                 prefill_chunk=8)
    big = eng.submit(list(map(int, rng.randint(0, cfg.vocab_size, 20))),
                     max_new_tokens=4)
    ok = eng.submit(list(map(int, rng.randint(0, cfg.vocab_size, 6))),
                    max_new_tokens=4)
    steps = eng.run(max_steps=1000)
    # big needs 2 blocks; admitting would leave 0 < watermark(1), and no
    # pool state can ever satisfy it: rejected, not head-of-line wedged
    assert eng.rejected == 1
    assert big.state == RequestState.FINISHED and big.rejected
    assert big.output_tokens == []
    # the queue behind it still gets served, and the run terminates
    assert len(ok.output_tokens) == 4
    assert eng.total_finished == 1
    assert steps < 1000
    assert eng.blocks.free_blocks == eng.blocks.num_blocks


def test_paged_rejects_prompt_exceeding_table_width():
    """A prompt needing more blocks than the per-request table width
    (ceil(max_context / block_size)) can never be represented — it must be
    rejected at admission, not crash the table build."""
    from repro.serving.request import RequestState

    cfg, m, params = setup_model("granite-3-8b")
    rng = np.random.RandomState(5)
    serve = ServeConfig(policy="static", b_max=2, max_new_tokens=4,
                        kv_pool_tokens=2048, block_size=16, paged_kv=True)
    eng = Engine(m, params, serve, max_context=32, buckets=(1, 2),
                 prefill_chunk=8)   # max_blocks = 2, pool = 128 blocks
    big = eng.submit(list(map(int, rng.randint(0, cfg.vocab_size, 40))),
                     max_new_tokens=4)
    ok = eng.submit(list(map(int, rng.randint(0, cfg.vocab_size, 6))),
                    max_new_tokens=4)
    eng.run(max_steps=1000)
    assert big.state == RequestState.FINISHED and big.rejected
    assert big.output_tokens == []
    assert eng.rejected == 1
    assert len(ok.output_tokens) == 4
