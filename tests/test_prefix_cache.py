"""Ref-counted prefix sharing on the paged KV pool (DESIGN §10).

Covers the tentpole: BlockManager prefix index / refcount / LRU-cache
semantics, COW, zero-copy shared-block mapping, engine prefix-on vs -off
bitwise equivalence, engine-vs-sim hit-rate parity, eviction-then-reuse
pos hygiene, and logical-vs-physical telemetry."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import ServeConfig
from repro.config.registry import get_config
from repro.models.model import build_model
from repro.serving.engine import Engine
from repro.serving.kv_cache import BlockManager, prefix_cache_supported


def setup_model(arch="granite-3-8b"):
    cfg = get_config(arch, "reduced")
    m = build_model(cfg, dtype=jnp.float32)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


# ---------------------------------------------------------------------------
# BlockManager unit semantics


def toks(n, seed=0):
    rng = np.random.RandomState(seed)
    return list(map(int, rng.randint(0, 997, size=n)))


def test_match_maps_full_blocks_zero_alloc():
    bm = BlockManager(total_tokens=320, block_size=16, prefix_cache=True)
    p = toks(40)                      # 2 full blocks + 8-token tail
    bm.allocate(1, 0, 41)
    bm.commit_prefill(1, p, 40)
    free_before = bm.free_blocks
    cached = bm.acquire_prefix(2, p)
    assert cached == 32               # the partial tail block never matches
    assert bm.tables[2] == bm.tables[1][:2]
    assert bm.free_blocks == free_before       # zero new blocks consumed
    assert all(bm.ref[b] == 2 for b in bm.tables[2])


def test_full_hit_demotes_tail_block():
    """An exact-prompt hit must leave a non-empty suffix: the engine still
    needs last-position logits to sample the first output token."""
    bm = BlockManager(total_tokens=320, block_size=16, prefix_cache=True)
    p = toks(32)                      # exactly 2 full blocks
    bm.allocate(1, 0, 33)
    bm.commit_prefill(1, p, 32)
    cached = bm.acquire_prefix(2, p)
    assert cached == 16               # last matched block demoted
    assert len(bm.tables[2]) == 1


def test_divergent_prompt_stops_at_first_mismatch():
    bm = BlockManager(total_tokens=320, block_size=16, prefix_cache=True)
    p = toks(48)
    bm.allocate(1, 0, 49)
    bm.commit_prefill(1, p, 48)
    q = list(p)
    q[20] += 1                        # diverge inside block 1
    assert bm.acquire_prefix(2, q) == 16      # only block 0 matches
    bm.free(2)
    r = list(p[:16]) + toks(16, seed=9)       # same block 0, new block 1
    assert bm.acquire_prefix(3, r) == 16


def test_free_is_decref_and_blocks_stay_resident():
    bm = BlockManager(total_tokens=160, block_size=16, prefix_cache=True)
    p = toks(40)
    bm.allocate(1, 0, 41)
    bm.commit_prefill(1, p, 40)
    bm.acquire_prefix(2, p)
    freed = bm.free(1)
    # shared blocks survive (ref 2 -> 1); only the private tail frees
    assert all(b not in freed for b in bm.tables[2])
    assert all(bm.ref[b] == 1 for b in bm.tables[2])
    # now the last ref drops: registered blocks become evictable cache,
    # NOT free-list entries — and are still matchable
    bm.free(2)
    assert bm.cached_blocks == 2
    assert bm.acquire_prefix(3, p) == 32      # resurrected from the cache
    assert bm.cached_blocks == 0


def test_lru_eviction_under_pressure_and_stale_pos_release():
    bm = BlockManager(total_tokens=64, block_size=16, prefix_cache=True)  # 4 blocks
    a, b = toks(32, seed=1), toks(32, seed=2)
    bm.allocate(1, 0, 32); bm.commit_prefill(1, a, 32); bm.free(1)
    bm.allocate(2, 0, 32); bm.commit_prefill(2, b, 32); bm.free(2)
    assert bm.cached_blocks == 4 and bm.physical_free_blocks == 0
    # allocating 2 blocks evicts the LRU entries (request 1's, the oldest)
    assert bm.allocate(3, 0, 32)
    assert sorted(bm.take_released()) and bm.cache_evictions == 2
    assert bm.acquire_prefix(4, a) == 0       # a was evicted
    assert bm.acquire_prefix(5, b) == 16      # b survived (full-hit demote)


def test_cow_gives_private_copy_to_writer():
    bm = BlockManager(total_tokens=160, block_size=16, prefix_cache=True)
    p = toks(32)
    bm.allocate(1, 0, 33)
    bm.commit_prefill(1, p, 32)
    bm.acquire_prefix(2, p)                   # block 0 shared, ref == 2
    shared = bm.tables[2][0]
    pairs = bm.cow_range(2, 0, 8)             # write into the shared block
    assert pairs and pairs[0][0] == shared
    assert bm.tables[2][0] != shared
    assert bm.ref[shared] == 1 and bm.ref[bm.tables[2][0]] == 1
    assert bm.cow_copies == 1
    # unshared writes are free of COW
    assert bm.cow_range(1, 0, 32) == []


def test_cow_destination_not_queued_for_pos_clear():
    """A COW dst taken via cache eviction receives a full K/V+pos copy —
    it must NOT sit in the released queue, or the engine's next drain
    would wipe the copied pos rows and mask the block from attention."""
    bm = BlockManager(total_tokens=64, block_size=16, prefix_cache=True)  # 4 blocks
    p = toks(32, seed=3)
    bm.allocate(1, 0, 33)                     # 3 blocks
    bm.commit_prefill(1, p, 32)
    bm.acquire_prefix(2, p)                   # block 0 shared (ref 2)
    # park registered content in the cache so _pop_block must evict
    c = toks(16, seed=4)
    bm.allocate(3, 0, 16)                     # the last free block
    bm.commit_prefill(3, c, 16)
    bm.free(3)                                # registered -> evictable cache
    assert bm.physical_free_blocks == 0 and bm.cached_blocks == 1
    bm.take_released()
    pairs = bm.cow_range(2, 0, 8)
    assert bm.cache_evictions == 1            # dst came via eviction
    assert pairs
    dst = pairs[0][1]
    assert dst not in bm.take_released()


def test_chain_hash_is_content_exact():
    """sha256 chain: same tokens at a different prefix never match."""
    bm = BlockManager(total_tokens=320, block_size=16, prefix_cache=True)
    a, b = toks(16, seed=1), toks(16, seed=2)
    bm.allocate(1, 0, 33)
    bm.commit_prefill(1, a + b, 32)
    # b's content after a different first block must miss
    assert bm.acquire_prefix(2, b + b) == 0
    assert bm.acquire_prefix(3, a + b) == 16  # true prefix still hits


def test_logical_vs_physical_usage():
    bm = BlockManager(total_tokens=320, block_size=16, prefix_cache=True)
    p = toks(40)
    bm.allocate(1, 0, 41)                     # 3 blocks
    bm.commit_prefill(1, p, 40)
    bm.acquire_prefix(2, p)                   # maps 2 shared
    bm.allocate(2, 8 * 4, 9)                  # 1 private block for the tail
    assert bm.logical_used_tokens == 6 * 16   # 3 + 3 per-request footprints
    assert bm.physical_used_tokens == 4 * 16  # deduped: 3 + 1 distinct
    assert bm.free_tokens == (20 - 4) * 16


def test_family_gate():
    assert prefix_cache_supported(get_config("granite-3-8b"))
    assert not prefix_cache_supported(get_config("mamba2-2.7b"))
    assert not prefix_cache_supported(get_config("recurrentgemma-9b"))


# ---------------------------------------------------------------------------
# engine-level equivalence


@pytest.mark.parametrize("chunked", [False, True])
def test_engine_prefix_on_off_bitwise_identical(chunked):
    """Shared-system-prompt burst: decoded tokens bitwise-identical with
    prefix caching on vs off, zero copy bytes for shared-block mapping, and
    a nonzero hit rate when on."""
    cfg, m, params = setup_model()
    rng = np.random.RandomState(0)
    system = list(map(int, rng.randint(0, cfg.vocab_size, size=40)))
    prompts = [system + list(map(int, rng.randint(0, cfg.vocab_size,
                                                  size=6 + i)))
               for i in range(4)]

    def run(prefix):
        serve = ServeConfig(policy="static", b_max=4, max_new_tokens=5,
                            kv_pool_tokens=2048, chunked_prefill=chunked,
                            chunk_budget_tokens=16, n_prefill_lanes=2,
                            paged_kv=True, prefix_cache=prefix)
        eng = Engine(m, params, serve, max_context=128, buckets=(1, 2, 4),
                     prefill_chunk=8)
        hs = [eng.submit(prompts[0], max_new_tokens=5)]
        eng.run()                     # wave 1 warms the index
        hs += [eng.submit(p, max_new_tokens=5) for p in prompts]
        eng.run()
        assert eng.total_finished == 5
        return [h.output_tokens for h in hs], eng

    out_off, _ = run(False)
    out_on, eng = run(True)
    assert out_off == out_on
    assert eng.copy_rows == 0 and eng.copy_bytes == 0
    s = eng.summary()
    assert s["prefix_hit_tokens"] >= 2 * 16   # wave-2 identical prompt hits
    assert s["prefix_hit_rate"] > 0
    # every still-shared/cached block accounted: logical >= physical
    assert s["logical_used_tokens"] >= s["physical_used_tokens"]


@pytest.mark.parametrize("chunked", [False, True])
def test_engine_eviction_reuse_keeps_outputs_identical(chunked):
    """Cache-evicted blocks are reused by new tenants: their stale pos rows
    must be cleared BEFORE the tenant's first attention read (the
    non-chunked path prefills inside the admission loop), or phantom keys
    corrupt attention. Small pool, many distinct prompts, then a
    re-arrival — outputs must match prefix-off."""
    cfg, m, params = setup_model()
    rng = np.random.RandomState(7)
    prompts = [list(map(int, rng.randint(0, cfg.vocab_size, size=36)))
               for _ in range(5)]

    def run(prefix):
        serve = ServeConfig(policy="static", b_max=2, max_new_tokens=4,
                            kv_pool_tokens=128, block_size=16,
                            chunked_prefill=chunked, chunk_budget_tokens=16,
                            paged_kv=True, prefix_cache=prefix)
        eng = Engine(m, params, serve, max_context=64, buckets=(1, 2),
                     prefill_chunk=8)
        outs = []
        for p in prompts + [prompts[0]]:
            h = eng.submit(p, max_new_tokens=4)
            eng.run(max_steps=2000)
            outs.append(h.output_tokens)
        return outs, eng

    out_off, _ = run(False)
    out_on, eng = run(True)
    assert out_off == out_on
    assert eng.blocks.cache_evictions > 0     # the pool really did recycle
    assert eng.copy_bytes == 0


def test_engine_preempted_request_rehits_its_own_blocks():
    """Recompute-after-preemption re-probes the index: the evicted request's
    own just-cached prompt blocks are mapped back, skipping the re-prefill
    of everything but the tail."""
    bm = BlockManager(total_tokens=320, block_size=16, prefix_cache=True)
    p = toks(48)
    bm.allocate(1, 0, 49)
    bm.commit_prefill(1, p, 48)
    bm.free(1)                                # preemption decrefs to cache
    assert bm.acquire_prefix(1, p) == 32      # full-hit demotion: 3 - 1


def test_engine_vs_sim_hit_rates_agree():
    """DESIGN §10 parity: identical token stream, wave-bursted, ample pool
    -> engine and sim prefix hit rates are exactly equal."""
    from repro.serving.cost_model import CostModel, PROFILES
    from repro.serving.sim import ServingSimulator, LengthDist
    from repro.serving.workload import feed_tokens, shared_prefix

    cfg, m, params = setup_model()
    arrivals = shared_prefix(rate=4.0, n=10, vocab_size=cfg.vocab_size,
                             n_system_prompts=2, system_len=48,
                             user_len=(4, 10), mean_out=6.0,
                             p_followup=0.8, max_turns=3, turn_gap_s=100.0,
                             seed=3)
    waves = {}
    for t, tk, lo in arrivals:
        waves.setdefault(int(t // 50), []).append((t, tk, lo))
    serve = ServeConfig(policy="static", b_max=4, max_new_tokens=6,
                        kv_pool_tokens=4096, chunked_prefill=True,
                        chunk_budget_tokens=24, n_prefill_lanes=2,
                        paged_kv=True, prefix_cache=True)

    eng = Engine(m, params, serve, max_context=256, buckets=(1, 2, 4),
                 prefill_chunk=8)
    for k in sorted(waves):
        for _, tk, _ in waves[k]:
            eng.submit(list(tk), max_new_tokens=6)
        eng.run(max_steps=5000)

    sim = ServingSimulator(cfg, serve, CostModel(cfg, PROFILES["a100x8"]),
                           LengthDist(mean_in=60, mean_out=6), seed=0,
                           prefill_chunk=8, max_context=256)
    feed_tokens(sim, [(50.0 * (i + 1), tk, 6)
                      for i, k in enumerate(sorted(waves))
                      for _, tk, _ in waves[k]])
    res = sim.run()
    assert eng.blocks.prefix_query_tokens == sim.blocks.prefix_query_tokens
    assert eng.blocks.prefix_hit_tokens == sim.blocks.prefix_hit_tokens
    assert eng.summary()["prefix_hit_rate"] == res.prefix_hit_rate > 0


def test_sim_charges_only_suffix_to_prefill_budget():
    """A wave-2 request whose prompt is fully cached finishes its (tiny)
    suffix prefill in far fewer fused steps than an uncached run."""
    from repro.serving.cost_model import CostModel, PROFILES
    from repro.serving.sim import ServingSimulator, LengthDist
    from repro.serving.workload import feed_tokens

    cfg = get_config("granite-3-8b")
    p = toks(128, seed=5)

    def run(prefix):
        serve = ServeConfig(policy="static", b_max=2, max_new_tokens=4,
                            kv_pool_tokens=4096, chunked_prefill=True,
                            chunk_budget_tokens=16, paged_kv=True,
                            prefix_cache=prefix)
        sim = ServingSimulator(cfg, serve,
                               CostModel(cfg, PROFILES["a100x8"]),
                               LengthDist(mean_in=128, mean_out=4), seed=0,
                               prefill_chunk=16)
        feed_tokens(sim, [(0.0, p, 4), (1000.0, p, 4)])
        res = sim.run()
        assert res.finished == 2
        return sim, res

    sim_off, _ = run(False)
    sim_on, _ = run(True)
    assert sim_on.blocks.prefix_hit_tokens == 112     # 8 blocks - demoted
    # prefill work: off prefills 2*128 tokens, on prefills 128 + 16
    assert sim_on.tel.prefill_tokens_total \
        < sim_off.tel.prefill_tokens_total - 64


def test_paged_off_path_unchanged_by_prefix_flag():
    """prefix_cache without paged_kv must be inert: byte-for-byte the
    legacy contiguous behavior."""
    cfg, m, params = setup_model()
    rng = np.random.RandomState(1)
    prompts = [list(map(int, rng.randint(0, cfg.vocab_size, size=20)))
               for _ in range(3)]

    def run(prefix):
        serve = ServeConfig(policy="memory", b_max=2, max_new_tokens=4,
                            kv_pool_tokens=1024, chunked_prefill=True,
                            chunk_budget_tokens=16, paged_kv=False,
                            prefix_cache=prefix)
        eng = Engine(m, params, serve, max_context=64, buckets=(1, 2),
                     prefill_chunk=8)
        hs = [eng.submit(p, max_new_tokens=4) for p in prompts]
        eng.run()
        return [h.output_tokens for h in hs], eng

    out_a, eng_a = run(False)
    out_b, eng_b = run(True)
    assert out_a == out_b
    assert not eng_b.prefix
    assert eng_b.blocks.prefix_hit_tokens == 0
