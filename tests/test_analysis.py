"""Flight-rules static analysis (DESIGN §13): rule-by-rule coverage over
paired good/bad fixture trees (exact rule IDs, messages and file:line
anchors), allowlist hygiene (justification / staleness / count drift),
seeded-violation detection against copies of the REAL anchor files, and
the tier-1 gate that runs the full suite over the live tree.
"""
import shutil
from pathlib import Path

import pytest

from repro.analysis import ALLOWLIST, Allow, Tree, run
from repro.analysis.framework import MIN_REASON, apply_allowlist

REPO = Path(__file__).resolve().parents[1]
FIX = REPO / "tests" / "fixtures" / "analysis"

ENGINE = "src/repro/serving/engine.py"
SIM = "src/repro/serving/sim.py"
CONFIG = "src/repro/config/base.py"

OK_REASON = "fixture sync point retained deliberately for this test"


def bad_report(**kw):
    return run(Tree(root=FIX / "bad"), **kw)


# -- per-rule fixture coverage: exact IDs, messages, anchors -----------------

def test_host_sync_bad_fixture_exact_anchors():
    r = bad_report(rule_ids=["host-sync"])
    got = {(f.path, f.line, f.scope) for f in r.findings}
    assert got == {(ENGINE, 13, "Engine.step"),
                   (ENGINE, 14, "Engine.step"),
                   (ENGINE, 15, "Engine.step")}
    by_line = {f.line: f.message for f in r.findings}
    assert "jax.block_until_ready" in by_line[13]
    assert ".item() pulls a device scalar" in by_line[14]
    assert "np.asarray" in by_line[15]
    assert all(f.rule == "host-sync" for f in r.findings)
    assert r.findings[0].anchor == f"{ENGINE}:13"


def test_allocator_encapsulation_bad_fixture_exact_anchors():
    r = bad_report(rule_ids=["allocator-encapsulation"])
    got = {(f.line, f.scope) for f in r.findings}
    assert got == {(19, "Engine.evict"), (20, "Engine.evict"),
                   (21, "Engine.evict")}
    msgs = {f.line: f.message for f in r.findings}
    assert "BlockManager.ref (assignment)" in msgs[19]
    assert "BlockManager.tables (.append())" in msgs[20]
    assert "BlockManager.tables (del)" in msgs[21]
    assert all(f.path == ENGINE for f in r.findings)


def test_counter_parity_bad_fixture_exact_anchors():
    r = bad_report(rule_ids=["counter-parity"])
    eng = [f for f in r.findings if f.path == ENGINE]
    sim = [f for f in r.findings if f.path == SIM]
    assert [(f.line, f.scope) for f in eng] == [(26, "Engine.summary")]
    assert "'preemptions' has no SimResult twin" in eng[0].message
    # oom_events (field) and throughput (@property) both lack summary keys;
    # batch_trace is a List and structurally exempt
    assert {(f.line, f.scope) for f in sim} == \
        {(9, "SimResult"), (13, "SimResult")}
    assert any("'oom_events'" in f.message for f in sim)
    assert any("'throughput'" in f.message for f in sim)


def test_config_wiring_bad_fixture_exact_anchors():
    r = bad_report(rule_ids=["config-wiring"])
    msgs = {(f.line, f.message) for f in r.findings}
    assert all(f.path == CONFIG for f in r.findings)
    assert {line for line, _ in msgs} == {8, 9, 10}
    assert any("dead ServeConfig field 'scheduling_interval'" in m
               for _, m in msgs)
    assert any("'b_min' is not wired through the serving CLI" in m
               for _, m in msgs)
    assert any("'eps_m' is undocumented" in m for _, m in msgs)


def test_good_fixture_clean_under_justified_allowlist():
    allows = [Allow("host-sync", ENGINE, "Engine.warmup", 1, OK_REASON)]
    r = run(Tree(root=FIX / "good"), allows=allows)
    assert r.ok, (r.findings, r.problems)
    # and without the allowlist the sync point surfaces
    r2 = run(Tree(root=FIX / "good"))
    assert [(f.rule, f.scope) for f in r2.findings] == \
        [("host-sync", "Engine.warmup")]


# -- allowlist hygiene -------------------------------------------------------

def test_allowlist_requires_justification():
    allows = [Allow("host-sync", ENGINE, "Engine.warmup", 1, "perf")]
    r = run(Tree(root=FIX / "good"), allows=allows)
    assert not r.ok
    assert len(r.problems) == 1
    assert "unjustified allowlist entry" in r.problems[0].message
    assert str(MIN_REASON) in r.problems[0].message
    # the finding is NOT suppressed by an unjustified entry
    assert [f.rule for f in r.findings] == ["host-sync"]


def test_allowlist_stale_entry_fails():
    allows = [Allow("host-sync", ENGINE, "Engine.warmup", 1, OK_REASON),
              Allow("host-sync", ENGINE, "Engine.gone", 2, OK_REASON)]
    r = run(Tree(root=FIX / "good"), allows=allows)
    assert not r.ok and not r.findings
    assert len(r.problems) == 1
    assert "stale allowlist entry" in r.problems[0].message


def test_allowlist_count_drift_fails():
    allows = [Allow("host-sync", ENGINE, "Engine.step", 2, OK_REASON)]
    r = run(Tree(root=FIX / "bad"), rule_ids=["host-sync"], allows=allows)
    assert not r.ok
    assert any("count drift" in p.message and "2 finding(s) but 3 match"
               in p.message for p in r.problems)


# -- seeded violations against the REAL anchor files -------------------------

@pytest.fixture()
def seeded(tmp_path):
    """Copy the real anchor files into a scratch tree ready for seeding.
    Relative paths match the repo, so the production ALLOWLIST applies."""
    for rel in [ENGINE, SIM, "src/repro/serving/kv_cache.py",
                CONFIG, "src/repro/launch/serve.py", "README.md"]:
        dst = tmp_path / rel
        dst.parent.mkdir(parents=True, exist_ok=True)
        shutil.copy(REPO / rel, dst)
    shutil.copytree(REPO / "docs", tmp_path / "docs")
    return tmp_path


def seed(root, rel, old="", new="", append=""):
    p = root / rel
    text = p.read_text()
    if old:
        assert old in text, f"seed anchor {old!r} missing from {rel}"
        text = text.replace(old, new)
    p.write_text(text + append)


def test_seeded_unallowlisted_block_until_ready_caught(seeded):
    seed(seeded, ENGINE, append=(
        "\n\ndef _sneaky_sync(x):\n"
        "    return jax.block_until_ready(x)\n"))
    r = run(Tree(root=seeded), allows=ALLOWLIST)
    assert not r.ok
    assert any(f.rule == "host-sync" and f.scope == "_sneaky_sync"
               for f in r.findings)


def test_seeded_blockmanager_mutation_caught(seeded):
    seed(seeded, ENGINE, append=(
        "\n\ndef _drift(blocks, b):\n"
        "    blocks.ref[b] -= 1\n"))
    r = run(Tree(root=seeded), allows=ALLOWLIST)
    assert not r.ok
    assert any(f.rule == "allocator-encapsulation"
               and "BlockManager.ref" in f.message
               and f.scope == "_drift" for f in r.findings)


def test_seeded_summary_only_counter_caught(seeded):
    seed(seeded, ENGINE,
         old='"finished": self.total_finished,',
         new='"finished": self.total_finished,\n'
             '            "phantom_counter": 0.0,')
    r = run(Tree(root=seeded), allows=ALLOWLIST)
    assert not r.ok
    assert any(f.rule == "counter-parity" and "'phantom_counter'"
               in f.message for f in r.findings)


def test_seeded_unwired_serveconfig_field_caught(seeded):
    seed(seeded, CONFIG,
         old="    b_max: int = 256",
         new="    b_max: int = 256\n    phantom_knob: int = 0")
    # read somewhere under src/ so only the CLI wiring is missing
    seed(seeded, ENGINE, append=(
        "\n\ndef _read_phantom(serve):\n"
        "    return serve.phantom_knob\n"))
    r = run(Tree(root=seeded), allows=ALLOWLIST)
    assert not r.ok
    assert any(f.rule == "config-wiring"
               and "'phantom_knob' is not wired" in f.message
               for f in r.findings)


def test_seeded_dead_serveconfig_field_caught(seeded):
    seed(seeded, CONFIG,
         old="    b_max: int = 256",
         new="    b_max: int = 256\n    phantom_dead: int = 0")
    r = run(Tree(root=seeded), allows=ALLOWLIST)
    assert any(f.rule == "config-wiring"
               and "dead ServeConfig field 'phantom_dead'" in f.message
               for f in r.findings)


# -- the tier-1 gate: the live tree must be clean ----------------------------

def test_live_tree_clean():
    r = run(Tree(root=REPO), allows=ALLOWLIST)
    assert r.ok, "\n".join(str(f) for f in r.findings + r.problems)
    # the allowlist is fully consumed: every entry matched (no problems)
    # and the engine's sync points stayed within their declared counts
    assert r.per_rule["host-sync"] == sum(
        a.count for a in ALLOWLIST if a.rule == "host-sync")


def test_report_json_round_trip():
    import json
    r = run(Tree(root=FIX / "bad"), rule_ids=["host-sync"])
    data = json.loads(r.to_json())
    assert data["ok"] is False
    assert data["per_rule"] == {"host-sync": 3}
    assert data["findings"][0]["path"] == ENGINE
