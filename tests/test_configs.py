"""The 10 assigned architecture configs must match the assignment exactly."""
import pytest

from repro.config.base import ArchFamily
from repro.config.registry import get_config, list_archs

ASSIGNED = {
    # arch: (family, L, d_model, H, kv, d_ff, vocab)
    "qwen2-moe-a2.7b": ("moe", 24, 2048, 16, 16, 1408, 151936),
    "recurrentgemma-9b": ("hybrid", 38, 4096, 16, 1, 12288, 256000),
    "seamless-m4t-medium": ("encdec", 12, 1024, 16, 16, 4096, 256206),
    "qwen1.5-32b": ("dense", 64, 5120, 40, 40, 27392, 152064),
    "granite-3-8b": ("dense", 40, 4096, 32, 8, 12800, 49155),
    "mistral-nemo-12b": ("dense", 40, 5120, 32, 8, 14336, 131072),
    "starcoder2-7b": ("dense", 32, 4608, 36, 4, 18432, 49152),
    "kimi-k2-1t-a32b": ("moe", 61, 7168, 64, 8, 2048, 163840),
    "mamba2-2.7b": ("ssm", 64, 2560, 0, 0, 0, 50280),
    "llama-3.2-vision-90b": ("vlm", 80, 8192, 64, 8, 28672, 128256),
}


def test_all_archs_registered():
    assert sorted(list_archs()) == sorted(ASSIGNED)


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_exact_dims(arch):
    fam, L, d, H, kv, ff, V = ASSIGNED[arch]
    c = get_config(arch)
    assert c.family == ArchFamily(fam)
    assert c.num_layers == L
    assert c.d_model == d
    assert c.num_heads == H
    assert c.num_kv_heads == kv
    assert c.d_ff == ff
    assert c.vocab_size == V


def test_moe_structure():
    q = get_config("qwen2-moe-a2.7b")
    assert q.moe.num_experts == 60 and q.moe.num_experts_per_tok == 4
    assert q.moe.num_shared_experts == 4
    k = get_config("kimi-k2-1t-a32b")
    assert k.moe.num_experts == 384 and k.moe.num_experts_per_tok == 8


def test_param_scales():
    # sanity: total params in the right ballpark per the model names
    assert 0.9e12 < get_config("kimi-k2-1t-a32b").param_count() < 1.2e12
    assert 30e9 < get_config("kimi-k2-1t-a32b").active_param_count() < 40e9
    assert 2.4e9 < get_config("mamba2-2.7b").param_count() < 3.1e9
    assert 7e9 < get_config("granite-3-8b").param_count() < 9e9
    assert 80e9 < get_config("llama-3.2-vision-90b").param_count() < 95e9


def test_vlm_is_100_layers_total():
    c = get_config("llama-3.2-vision-90b")
    assert c.num_layers + c.num_cross_layers == 100


def test_reduced_variants_small():
    for arch in ASSIGNED:
        r = get_config(arch, "reduced")
        assert r.d_model <= 512
        assert r.num_layers <= 3
        if r.moe:
            assert r.moe.num_experts <= 4


def test_kv_bytes_per_token():
    # SSM has no growing KV; hybrid grows only in its attention layers
    assert get_config("mamba2-2.7b").kv_bytes_per_token() == 0
    rg = get_config("recurrentgemma-9b")
    n_att = sum(1 for k in rg.layer_kinds() if k == "attention")
    assert rg.kv_bytes_per_token() == 2 * n_att * 1 * 256 * 2
