"""Differential engine-vs-sim harness (DESIGN §7/§11).

The simulator is the engine's discrete-event twin: the same controller
stack, interval for interval. This harness drives randomized workloads
through BOTH under the same config and asserts exact parity on the
controller-visible counters — admitted / preemptions / oom_events /
rejected / swap_outs / swap_ins — and on the completion and rejection
sets. The two-tier swap policy (DESIGN §11) must land green under it with
swap enabled and disabled.

Example counts are bounded (the engine runs real jit-compiled steps) so
the harness fits the tier-1 CI budget.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.config.base import ServeConfig
from repro.config.registry import get_config
from repro.core.telemetry import Telemetry
from repro.models.model import build_model
from repro.serving.cost_model import CostModel, PROFILES
from repro.serving.engine import Engine
from repro.serving.request import Request
from repro.serving.sim import LengthDist, ServingSimulator
from repro.serving.workload import reference_trace

MAX_CONTEXT = 96
_MODEL = {}


def setup_model():
    if not _MODEL:
        cfg = get_config("granite-3-8b", "reduced")
        m = build_model(cfg, dtype=jnp.float32)
        _MODEL["cfg"] = cfg
        _MODEL["m"] = m
        _MODEL["params"] = m.init(jax.random.PRNGKey(0))
    return _MODEL["cfg"], _MODEL["m"], _MODEL["params"]


def run_pair(prompt_lens, max_new, serve, seed=0):
    """Run the identical workload (all arrivals at t=0) through the real
    engine and the simulator twin; return both."""
    cfg, m, params = setup_model()
    cost = CostModel(cfg, PROFILES["a100x8"])
    eng = Engine(m, params, serve, max_context=MAX_CONTEXT,
                 buckets=(1, 2, 4, 8), prefill_chunk=8, cost=cost)
    rng = np.random.RandomState(seed)
    hs = []
    for pl in prompt_lens:
        toks = list(map(int, rng.randint(0, cfg.vocab_size, size=pl)))
        hs.append(eng.submit(toks, max_new_tokens=max_new, arrival_time=0.0))
    eng.run(max_steps=20_000)

    sim = ServingSimulator(cfg, serve, cost,
                           LengthDist(mean_in=float(np.mean(prompt_lens)),
                                      mean_out=float(max_new)),
                           seed=0, prefill_chunk=8, max_context=MAX_CONTEXT)
    # the engine's telemetry starts prior-free — match it exactly
    sim.tel = Telemetry()
    for i, pl in enumerate(prompt_lens):
        # engine.submit caps max_new at the context budget; mirror it
        mx = min(max_new, MAX_CONTEXT - pl - 1)
        sim.waiting.append(Request(rid=i, arrival_time=0.0, prompt_len=pl,
                                   max_new_tokens=mx))
    sim._all.extend(sim.waiting)
    res = sim.run(max_steps=20_000)
    return eng, hs, sim, res


def assert_parity(eng, hs, sim, res, ctx=""):
    assert eng.admitted_total == res.admitted, ctx
    assert eng.preemptions == res.preemptions, ctx
    assert eng.oom_events == res.oom_events, ctx
    assert eng.rejected == res.rejected, ctx
    assert eng.swap_outs == res.swap_outs, ctx
    assert eng.swap_ins == res.swap_ins, ctx
    # both twins charge model-level KV payload bytes per swapped block
    assert eng.swap_out_bytes == res.swap_out_bytes, ctx
    assert eng.swap_in_bytes == res.swap_in_bytes, ctx
    eng_done = {h.rid for h in hs
                if h.state.value == "finished" and not h.rejected}
    sim_done = {r.rid for r in sim._all
                if r.state.value == "finished" and not r.rejected}
    assert eng_done == sim_done, ctx
    eng_rej = {h.rid for h in hs if h.rejected}
    sim_rej = {r.rid for r in sim._all if r.rejected}
    assert eng_rej == sim_rej, ctx
    # per-request goodput verdicts (DESIGN §15) agree request for
    # request: under the regimes this harness runs (SLA disabled, or
    # unmeetable) wall-clock and sim-clock verdicts provably coincide
    assert eng.sla_requests_met == res.sla_requests_met, ctx
    assert eng.goodput_tokens == res.goodput_tokens, ctx
    eng_met = {h.rid for h in hs if h.sla_met}
    sim_met = {r.rid for r in sim._all if r.sla_met}
    assert eng_met == sim_met, ctx
    # both drained completely
    assert not eng.waiting and not eng.active and not eng.prefilling \
        and not eng.swapped, ctx
    assert not sim.waiting and not sim.running and not sim.pending_prefill \
        and not sim.swapped, ctx


def serve_cfg(*, policy="static", b_max=4, pool_tokens=256, swap_blocks=0,
              chunked=True, lanes=2, budget=24, preempt="auto", overlap=0,
              ttft_sla=0.0):
    return ServeConfig(policy=policy, b_max=b_max, max_new_tokens=6,
                       kv_pool_tokens=pool_tokens, block_size=16,
                       chunked_prefill=chunked, chunk_budget_tokens=budget,
                       n_prefill_lanes=lanes, paged_kv=True,
                       swap_space_blocks=swap_blocks, preempt=preempt,
                       overlap_depth=overlap, ttft_sla_s=ttft_sla)


def run_trace_pair(events, serve):
    """Replay the identical multi-turn trace through the real engine and
    the simulator twin (arrival times collapsed to 0, matching run_pair's
    convention — the engine clock is wall time). Per-request output
    budgets follow the trace: max_new = min(l_out, config, context cap)
    on both sides, so the twins stop each request identically."""
    cfg, m, params = setup_model()
    cost = CostModel(cfg, PROFILES["a100x8"])
    eng = Engine(m, params, serve, max_context=MAX_CONTEXT,
                 buckets=(1, 2, 4, 8), prefill_chunk=8, cost=cost)
    hs = []
    for e in events:
        hs.append(eng.submit(list(e.tokens),
                             max_new_tokens=min(e.l_out,
                                                serve.max_new_tokens),
                             arrival_time=0.0))
    eng.run(max_steps=20_000)

    mi = sum(e.prompt_len for e in events) / len(events)
    mo = sum(e.l_out for e in events) / len(events)
    sim = ServingSimulator(cfg, serve, cost,
                           LengthDist(mean_in=mi, mean_out=mo),
                           seed=0, prefill_chunk=8, max_context=MAX_CONTEXT)
    sim.tel = Telemetry()
    for i, e in enumerate(events):
        # engine.submit caps max_new at the context budget; mirror it
        mx = min(e.l_out, serve.max_new_tokens,
                 MAX_CONTEXT - e.prompt_len - 1)
        sim.waiting.append(Request(rid=i, arrival_time=0.0,
                                   prompt_tokens=list(e.tokens),
                                   max_new_tokens=mx))
    sim._all.extend(sim.waiting)
    res = sim.run(max_steps=20_000)
    return eng, hs, sim, res


# ---------------------------------------------------------------------------
# fixed scenarios: the regimes the randomized sweep must also cover


@pytest.mark.parametrize("swap_blocks,preempt", [(0, "auto"), (16, "swap")])
@pytest.mark.parametrize("chunked", [False, True])
def test_differential_tight_pool_preemption(chunked, swap_blocks, preempt):
    """A pool too small for the batch forces preemptions; engine and sim
    must agree on every counter with swapping off AND forced on."""
    serve = serve_cfg(pool_tokens=160, swap_blocks=swap_blocks,
                      chunked=chunked, preempt=preempt, b_max=4)
    eng, hs, sim, res = run_pair([40, 44, 38, 46], max_new=12, serve=serve,
                                 seed=1)
    assert eng.preemptions > 0          # the regime actually triggered
    if swap_blocks:
        assert eng.swap_outs > 0 and eng.swap_ins > 0
    assert_parity(eng, hs, sim, res)


def test_differential_rejection_and_watermark():
    """Unservable prompts are rejected (not wedged) identically, and
    watermark deferrals count identically."""
    serve = serve_cfg(pool_tokens=128, b_max=4, chunked=True)
    # 90-token prompt: 6 blocks vs a 8-block pool with 1-block watermark
    eng, hs, sim, res = run_pair([90, 20, 88, 24], max_new=4, serve=serve,
                                 seed=2)
    assert_parity(eng, hs, sim, res)


def test_differential_memory_policy():
    """Alg-1 (memory policy) decisions feed back on telemetry that both
    twins must produce identically."""
    serve = serve_cfg(policy="memory", pool_tokens=256, b_max=8,
                      swap_blocks=12, preempt="swap")
    eng, hs, sim, res = run_pair([24, 18, 30, 12, 26, 20], max_new=5,
                                 serve=serve, seed=3)
    assert_parity(eng, hs, sim, res)


@pytest.mark.parametrize("overlap", [0, 1])
def test_differential_async_overlap(overlap):
    """The async dispatch-ahead pipeline (DESIGN §14) keeps the twins in
    exact counter parity at every depth: the engine defers telemetry
    feeds to retirement and the sim lags its feed queue by the same
    number of dispatched intervals, so Alg-1 reads identically stale
    snapshots in both."""
    serve = serve_cfg(policy="memory", pool_tokens=160, b_max=4,
                      swap_blocks=12, preempt="swap", overlap=overlap)
    eng, hs, sim, res = run_pair([40, 44, 38, 46, 26], max_new=12,
                                 serve=serve, seed=4)
    # the pressure regime triggered: Alg-1 defers at the watermark
    # (memory-aware admission preempts rarely — it under-admits instead)
    assert eng.oom_events > 0
    assert_parity(eng, hs, sim, res, ctx=f"overlap={overlap}")
    # the host/device split twins exist and partition the interval
    assert eng.summary()["step_host_s_mean"] > 0.0
    assert res.step_host_s_mean > 0.0 and res.step_device_s_mean > 0.0


@pytest.mark.parametrize("swap_blocks,overlap,sla", [
    (0, 0, 0.0),        # plain pipeline, SLA checks disabled
    (0, 2, 1e-9),       # dispatch-ahead depth 2, unmeetable TTFT SLO
    (16, 0, 1e-9),      # two-tier swap on, unmeetable TTFT SLO
    (16, 2, 0.0),       # swap + overlap together, SLA disabled
])
def test_differential_traced_load(swap_blocks, overlap, sla):
    """Replayed multi-turn trace (DESIGN §15) through both twins: exact
    parity on admitted/finished/rejected AND the goodput counters, with
    swap on/off and overlap depth 0/2. SLA regimes are chosen so the
    wall-clock (engine) and sim-clock verdicts provably coincide:
    disabled => met == finished; unmeetable => met == 0."""
    events = reference_trace(14, seed=5, vocab_size=512, base_rate=4.0,
                             burst_rate=16.0, period_s=20.0, duty=0.25,
                             n_system_prompts=2, system_len=12,
                             user_mean=8.0, out_mean=5.0, length_cv=0.5,
                             p_followup=0.7, max_turns=3, turn_gap_s=2.0)
    assert any(e.parent_id is not None for e in events)
    serve = serve_cfg(policy="memory", pool_tokens=160, b_max=4,
                      swap_blocks=swap_blocks,
                      preempt="swap" if swap_blocks else "auto",
                      overlap=overlap, ttft_sla=sla)
    eng, hs, sim, res = run_trace_pair(events, serve)
    # every traced request resolves (finished or rejected) on both sides
    assert all(h.state.value == "finished" or h.rejected for h in hs)
    if sla > 0.0:
        assert eng.sla_requests_met == 0 and res.sla_requests_met == 0
    else:
        assert eng.sla_requests_met == eng.total_finished
    assert_parity(eng, hs, sim, res,
                  ctx=f"swap={swap_blocks} overlap={overlap} sla={sla}")


# ---------------------------------------------------------------------------
# randomized sweep (bounded example count: each example runs the real
# engine — keep tier-1 wall-time in budget)


@given(st.integers(0, 10_000),
       st.integers(2, 5),
       st.sampled_from([10, 12, 16]),          # pool blocks
       st.sampled_from([0, 8, 24]),            # swap space blocks
       st.booleans(),                          # chunked prefill
       st.sampled_from(["static", "memory"]),
       st.sampled_from(["auto", "swap"]),
       st.sampled_from([0, 1]))                # overlap depth (DESIGN §14)
@settings(max_examples=8, deadline=None)
def test_differential_randomized(seed, n_req, pool_blocks, swap_blocks,
                                 chunked, policy, preempt, overlap):
    rng = np.random.RandomState(seed)
    prompt_lens = [int(rng.randint(6, 44)) for _ in range(n_req)]
    serve = serve_cfg(policy=policy, b_max=4,
                      pool_tokens=pool_blocks * 16,
                      swap_blocks=swap_blocks, chunked=chunked,
                      lanes=int(rng.randint(1, 3)), preempt=preempt,
                      overlap=overlap)
    eng, hs, sim, res = run_pair(prompt_lens, max_new=int(rng.randint(2, 7)),
                                 serve=serve, seed=seed)
    assert_parity(eng, hs, sim, res,
                  ctx=f"seed={seed} pool={pool_blocks} swap={swap_blocks} "
                      f"chunked={chunked} policy={policy} preempt={preempt} "
                      f"overlap={overlap}")
