"""Async dispatch-ahead pipeline tests (DESIGN §14).

Two halves:

* engine bitwise identity — the acceptance criterion: for the same
  workload, `overlap_depth=1` (and deeper) must produce BITWISE-identical
  output tokens, step counts and scheduling counters as the synchronous
  loop (`overlap_depth=0`), across paged/contiguous layouts, PD fusion
  on/off, and the two-tier swap path. The pipeline defers token readback
  and telemetry feeds, never values: every scheduling decision is
  value-independent (token COUNTS drive finishes/grows/preemption), and
  deferred inputs are spliced back in on device.

* shadow-epoch invariants (hypothesis) — the BlockManager machinery the
  pipeline leans on: an open epoch parks frees without changing any
  headroom count (epoch-free twin parity), deferred blocks are reused
  only after the free list drains, `shadow_commit` returns them in free
  order, and `shadow_begin` -> arbitrary mutations -> `shadow_rollback`
  is a no-op.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
import random as _random
from _hypothesis_compat import given, settings, strategies as st

from repro.config.base import ServeConfig
from repro.config.registry import get_config
from repro.models.model import build_model
from repro.serving.engine import Engine
from repro.serving.kv_cache import BlockManager

MAX_CONTEXT = 96
_MODEL = {}

_COUNTERS = ("finished", "admitted", "preemptions", "oom_events",
             "rejected", "decode_steps", "total_tokens", "prefill_tokens",
             "swap_outs", "swap_ins", "swap_out_bytes", "swap_in_bytes",
             "cache_evictions", "copy_rows")


def setup_model():
    if not _MODEL:
        cfg = get_config("granite-3-8b", "reduced")
        m = build_model(cfg, dtype=jnp.float32)
        _MODEL["cfg"] = cfg
        _MODEL["m"] = m
        _MODEL["params"] = m.init(jax.random.PRNGKey(0))
    return _MODEL["cfg"], _MODEL["m"], _MODEL["params"]


def run_engine(depth, *, paged=True, chunked=True, swap_blocks=0,
               pool_tokens=1024, policy="memory", b_max=8,
               prompt_lens=(5, 9, 17, 4, 23, 12), max_new=6, seed=0):
    """One full engine run; returns (steps, per-request outputs, summary)."""
    cfg, m, params = setup_model()
    serve = ServeConfig(policy=policy, b_max=b_max, block_size=16,
                        max_new_tokens=max_new, kv_pool_tokens=pool_tokens,
                        paged_kv=paged, chunked_prefill=chunked,
                        chunk_budget_tokens=16, n_prefill_lanes=2,
                        batch_buckets=(1, 2, 4, 8),
                        swap_space_blocks=swap_blocks,
                        preempt="swap" if swap_blocks else "auto",
                        overlap_depth=depth)
    eng = Engine(m, params, serve, max_context=MAX_CONTEXT,
                 buckets=(1, 2, 4, 8), prefill_chunk=8)
    rng = np.random.RandomState(seed)
    reqs = []
    for n in prompt_lens:
        toks = list(map(int, rng.randint(0, cfg.vocab_size, size=n)))
        reqs.append(eng.submit(toks, arrival_time=0.0))
    steps = eng.run(max_steps=20_000)
    # retirement patched every placeholder: no residual Nones anywhere
    for r in reqs:
        assert all(t is not None for t in r.output_tokens), r.rid
    return steps, [tuple(r.output_tokens) for r in reqs], eng.summary(), eng


def assert_bitwise(depth, **kw):
    s0, o0, m0, _ = run_engine(0, **kw)
    s1, o1, m1, e1 = run_engine(depth, **kw)
    ctx = f"depth={depth} {kw}"
    assert o0 == o1, ctx
    assert s0 == s1, ctx
    for k in _COUNTERS:
        assert m0[k] == m1[k], (ctx, k, m0[k], m1[k])
    # the pipeline fully drained before run() reported idle
    assert not e1._inflight, ctx
    return m0, m1


@pytest.mark.parametrize("paged,chunked", [(True, True), (True, False),
                                           (False, True), (False, False)])
def test_bitwise_sync_vs_async(paged, chunked):
    """Depth 1 == depth 0, bit for bit, on all four layout/fusion combos."""
    assert_bitwise(1, paged=paged, chunked=chunked)


def test_bitwise_under_swap_pressure():
    """A pool tight enough to force swap-out/swap-in preemptions keeps
    bitwise identity: a swapped request's pending (un-retired) token
    survives offload and feeds its post-restore decode unchanged."""
    m0, m1 = assert_bitwise(1, paged=True, chunked=True, swap_blocks=16,
                            pool_tokens=160, policy="static", b_max=4,
                            prompt_lens=(40, 44, 38, 46), max_new=12,
                            seed=1)
    assert m0["preemptions"] > 0 and m0["swap_ins"] > 0


def test_bitwise_depth_two():
    """The pipeline generalizes past one interval: pending device tokens
    chain across consecutive un-retired decode steps."""
    assert_bitwise(2, paged=True, chunked=True)


def test_host_device_split_recorded():
    """Satellite: the engine's summary carries the host-vs-device interval
    split, and the two traces partition each step's wall time."""
    _, _, summ, eng = run_engine(1, paged=True, chunked=True)
    assert summ["step_host_s_mean"] > 0.0
    assert summ["step_device_s_mean"] > 0.0
    assert len(eng.step_host_trace) == len(eng.step_device_trace)


def test_timestamps_stamped_at_retirement():
    """Satellite: TTFT/TBT/finish timestamps are stamped when the device
    step retires, so at depth 1 a request's first_token_time can only
    move LATER than dispatch — never before its prefill started."""
    _, _, _, eng = run_engine(1, paged=True, chunked=True)
    done = [r for r in (eng.waiting + eng.active) ] # drained: both empty
    assert not done
    for tr in eng.ttft_trace:
        assert tr >= 0.0
    assert len(eng.tbt_trace) == eng.decode_steps


# ---------------------------------------------------------------------------
# shadow-epoch invariants (pure BlockManager: fast, hypothesis-driven)


def _drive(bm, rng, n_ops, twin=None, commit_every=0):
    """Random allocate/free traffic; mirrored onto `twin` (epoch-free)
    when given. Returns per-op (free_blocks, physical, ok) observations."""
    live = []
    obs = []
    for i in range(n_ops):
        op = rng.random()
        if op < 0.55 or not live:
            rid = rng.randrange(1000)
            if rid in bm.tables or rid in getattr(bm, "swapped_tables", {}):
                continue
            toks = rng.randrange(1, 4 * bm.block_size)
            ok = bm.allocate(rid, 0, toks)
            if twin is not None:
                assert twin.allocate(rid, 0, toks) == ok
            if ok:
                live.append(rid)
            else:
                bm.free(rid)
                if twin is not None:
                    twin.free(rid)
        else:
            rid = live.pop(rng.randrange(len(live)))
            bm.free(rid)
            if twin is not None:
                twin.free(rid)
        if commit_every and i % commit_every == commit_every - 1:
            bm.shadow_commit()
            bm.shadow_begin()
        obs.append((bm.free_blocks, bm.physical_free_blocks))
        if twin is not None:
            assert (twin.free_blocks, twin.physical_free_blocks) == obs[-1]
    return obs


@given(st.integers(0, 10_000), st.integers(4, 24), st.integers(0, 5))
@settings(max_examples=40, deadline=None)
def test_epoch_count_invariance(seed, pool_blocks, commit_every):
    """Headroom parity: a manager running open shadow epochs (with commits
    at arbitrary cadence) reports the same free_blocks /
    physical_free_blocks and the same allocation verdicts as an epoch-free
    twin under identical traffic — epochs change WHICH ids are reused,
    never whether an allocation succeeds (DESIGN §14)."""
    rng = _random.Random(seed)
    bm = BlockManager(pool_blocks * 16, 16)
    twin = BlockManager(pool_blocks * 16, 16)
    bm.shadow_begin()
    _drive(bm, rng, 60, twin=twin, commit_every=commit_every)
    bm.shadow_commit()
    assert (bm.free_blocks, bm.physical_free_blocks) \
        == (twin.free_blocks, twin.physical_free_blocks)


def _observable(bm):
    return (list(bm._free), list(bm._deferred),
            {r: list(t) for r, t in bm.tables.items()},
            dict(bm.ref), list(bm._cached), dict(bm._hash_of),
            {r: list(t) for r, t in bm.swapped_tables.items()},
            bm.swap_out_blocks, bm.swap_in_blocks, bm.swapped_peak,
            bm.prefix_hit_tokens, bm.prefix_query_tokens,
            bm.cache_evictions, bm.cow_copies)


@given(st.integers(0, 10_000), st.integers(4, 24))
@settings(max_examples=40, deadline=None)
def test_shadow_rollback_restores(seed, pool_blocks):
    """begin -> arbitrary mutations -> rollback is a no-op: every piece of
    allocator state (free order included) returns to the snapshot."""
    rng = _random.Random(seed)
    bm = BlockManager(pool_blocks * 16, 16, prefix_cache=True)
    _drive(bm, rng, 20)          # non-trivial starting state, no epoch
    before = _observable(bm)
    bm.shadow_begin()
    _drive(bm, rng, 30)
    bm.shadow_rollback()
    assert _observable(bm) == before
    # rollback closed the epoch: a new begin is legal, a second rollback
    # is not
    with pytest.raises(RuntimeError):
        bm.shadow_rollback()
    bm.shadow_begin()
    bm.shadow_commit()


@given(st.integers(0, 10_000), st.integers(4, 16))
@settings(max_examples=30, deadline=None)
def test_shadow_commit_flushes_in_free_order(seed, pool_blocks):
    """Commit returns every parked block to the free list (deferred order
    preserved at the reuse end), leaves totals unchanged, and tolerates
    being called with no epoch open (the run's first retirement)."""
    rng = _random.Random(seed)
    bm = BlockManager(pool_blocks * 16, 16)
    bm.shadow_commit()           # no epoch open: a legal no-op
    bm.shadow_begin()
    _drive(bm, rng, 25)
    free_before = bm.free_blocks
    parked = list(bm._deferred)
    bm.shadow_commit()
    assert bm._deferred == []
    assert bm.free_blocks == free_before
    assert bm._free[len(bm._free) - len(parked):] == parked
    with pytest.raises(RuntimeError):
        bm.shadow_rollback()     # nothing open after a commit


def test_deferred_reused_only_after_free_list_drains():
    """While the free list is non-empty, allocation never touches parked
    blocks; once it drains, parked blocks are reused oldest-first, and
    only then does the cached (prefix) pool get evicted."""
    bm = BlockManager(8 * 16, 16)
    assert bm.allocate(1, 0, 3 * 16)
    bm.shadow_begin()
    bm.free(1)
    parked = list(bm._deferred)
    assert len(parked) == 3
    # 5 blocks remain truly free: they must all be consumed first
    assert bm.allocate(2, 0, 5 * 16)
    assert not any(b in parked for b in bm.tables[2])
    # next allocation can only be served from the parked set, oldest first
    assert bm.allocate(3, 0, 2 * 16)
    assert bm.tables[3] == parked[:2]
    bm.shadow_commit()


def test_epoch_double_begin_raises():
    bm = BlockManager(4 * 16, 16)
    bm.shadow_begin()
    with pytest.raises(RuntimeError):
        bm.shadow_begin()
    bm.shadow_rollback()
