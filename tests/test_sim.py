"""Simulator + cost-model tests: the paper's qualitative laws must emerge."""
import pytest

from repro.config.base import ServeConfig
from repro.config.registry import get_config
from repro.serving.cost_model import CostModel, PROFILES
from repro.serving.sim import LengthDist, ServingSimulator

CFG70 = get_config("granite-3-8b")  # stand-in; scale set by cost model


def run_sim(policy, b_max, n=400, sla=0.0, chunked=False, arrival=0.0,
            model=CFG70, hw="a100x8", seed=0, mean_in=128, mean_out=128,
            fixed=True, c0=0.0, c1=0.0):
    cost = CostModel(model, PROFILES[hw], c0_ms=c0, c1_ms=c1)
    lengths = LengthDist(mean_in=mean_in, mean_out=mean_out, fixed=fixed)
    serve = ServeConfig(policy=policy, b_max=b_max, d_sla_ms=sla,
                        max_new_tokens=mean_out * 4,
                        chunked_prefill=chunked)
    sim = ServingSimulator(model, serve, cost, lengths, seed=seed)
    sim.add_requests(n, arrival_rate=arrival)
    return sim.run()


# ---------------------------------------------------------------------------
# Fig 3 laws


def test_tau_step_linear_in_batch():
    cost = CostModel(CFG70, PROFILES["a100x8"])
    taus = [cost.tau_step_ms(b, 512.0) for b in (32, 64, 128, 256)]
    d1 = taus[1] - taus[0]
    d2 = taus[2] - taus[1]
    d3 = (taus[3] - taus[2]) / 2
    assert d2 == pytest.approx(2 * d1, rel=1e-6)
    assert d3 == pytest.approx(d1 * 2, rel=1e-6)  # slope constant


def test_throughput_concave_increasing():
    cost = CostModel(CFG70, PROFILES["a100x8"])
    bs = [64, 128, 192, 256, 320, 384]   # equal spacing for concavity check
    phi = [b / cost.tau_step_s(b, 512.0) for b in bs]
    assert all(b > a for a, b in zip(phi, phi[1:]))          # increasing
    gains = [b - a for a, b in zip(phi, phi[1:])]
    assert all(g2 < g1 for g1, g2 in zip(gains, gains[1:]))  # diminishing


def test_paper_fig3_anchor_points():
    """Calibrated profile reproduces Fig 3: b=100 -> ~50ms/~2000 tok/s;
    b=230 -> ~80ms/~2700 tok/s."""
    cost = CostModel(CFG70, PROFILES["paper-fig3"], c0_ms=28.0, c1_ms=0.225)
    t100 = cost.tau_step_ms(100, 500.0)
    t230 = cost.tau_step_ms(230, 500.0)
    assert t100 == pytest.approx(50.0, abs=2.0)
    assert t230 == pytest.approx(80.0, abs=2.0)
    assert 100 / (t100 / 1e3) == pytest.approx(2000, rel=0.05)
    assert 230 / (t230 / 1e3) == pytest.approx(2875, rel=0.08)


# ---------------------------------------------------------------------------
# dynamic vs static (Table I shape)


def test_dynamic_beats_static_throughput():
    st = run_sim("static", 256)
    dy = run_sim("memory", 4096)
    assert st.finished == dy.finished == 400
    assert dy.throughput_tok_s > st.throughput_tok_s * 1.05


def test_all_requests_complete_under_all_policies():
    for pol, sla in [("static", 0.0), ("memory", 0.0), ("sla", 60.0),
                     ("combined", 60.0)]:
        res = run_sim(pol, 256, n=150, sla=sla)
        assert res.finished == 150, pol


def test_sla_policy_tracks_latency_band():
    res = run_sim("sla", 512, n=400, sla=60.0)
    # mean TBT should settle near (under) the SLA once converged
    assert res.tbt_ms_mean <= 60.0 * 1.25
    assert res.sla_attainment >= 0.6


def test_combined_never_exceeds_memory_bound():
    res = run_sim("combined", 4096, n=300, sla=80.0)
    assert res.finished == 300
    assert res.oom_events == 0


def test_chunked_prefill_mode_completes():
    res = run_sim("memory", 512, n=200, chunked=True)
    assert res.finished == 200
    assert res.throughput_tok_s > 0


def test_poisson_arrivals_idle_advance():
    res = run_sim("memory", 256, n=100, arrival=50.0)
    assert res.finished == 100
    assert res.duration_s >= 100 / 50.0 * 0.5  # at least ~arrival span


def test_preemption_on_tight_pool():
    cost = CostModel(CFG70, PROFILES["a100x8"])
    lengths = LengthDist(mean_in=128, mean_out=128, cv_out=1.0)
    serve = ServeConfig(policy="static", b_max=512, max_new_tokens=2048,
                        kv_pool_tokens=40_000)
    sim = ServingSimulator(CFG70, serve, cost, lengths, seed=1)
    sim.add_requests(300)
    res = sim.run()
    assert res.finished == 300
    assert res.preemptions > 0 or res.oom_events > 0
