"""Telemetry regressions: arrival-rate span bug, cold-window sample count,
logical-vs-physical usage passthrough (DESIGN §1, §10)."""
from repro.core.telemetry import Telemetry


def test_arrival_rate_single_fresh_arrival_no_spike():
    """Pre-fix, the rate divided by `now - recent[0]`, so one arrival a
    millisecond ago read as ~1000 req/s (and up to 1e6 at the 1e-6 clamp),
    poisoning lambda(t). The denominator is the full horizon, clamped to
    elapsed time."""
    tel = Telemetry()
    tel.on_arrival(4.999, 10)
    rate = tel.arrival_rate(5.0, horizon=10.0)
    assert abs(rate - 1 / 5.0) < 1e-9          # clamped to elapsed time
    assert rate < 1.0                          # nowhere near the old spike


def test_arrival_rate_full_horizon():
    tel = Telemetry()
    for t in (91.0, 95.0, 99.0):
        tel.on_arrival(t, 10)
    assert abs(tel.arrival_rate(100.0, horizon=10.0) - 0.3) < 1e-9


def test_arrival_rate_empty():
    assert Telemetry().arrival_rate(100.0) == 0.0


def test_arrival_rate_excludes_stale():
    tel = Telemetry()
    tel.on_arrival(1.0, 10)
    assert tel.arrival_rate(100.0, horizon=10.0) == 0.0


def test_snapshot_tbt_samples_counts_window():
    tel = Telemetry()
    s0 = tel.snapshot(now=0.0, n_prefill=0, n_decode=0, free_tokens=0)
    assert s0.tbt_samples == 0 and s0.tbt_ms == 0.0
    tel.on_decode_step(12.5, 4)
    tel.on_decode_step(7.5, 4)
    s1 = tel.snapshot(now=1.0, n_prefill=0, n_decode=4, free_tokens=0)
    assert s1.tbt_samples == 2
    assert abs(s1.tbt_ms - 10.0) < 1e-9


def test_snapshot_logical_physical_passthrough():
    tel = Telemetry()
    s = tel.snapshot(now=0.0, n_prefill=0, n_decode=0, free_tokens=128,
                     logical_used_tokens=96, physical_used_tokens=64)
    assert s.logical_used_tokens == 96
    assert s.physical_used_tokens == 64
