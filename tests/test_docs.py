"""Docs stay honest: README/DESIGN links resolve, DESIGN section numbers
match every `DESIGN §N` reference in source docstrings, and the quickstart
entry points exist. Run standalone or as the CI docs link-check step."""
import re
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DESIGN = ROOT / "docs" / "DESIGN.md"
README = ROOT / "README.md"


def design_sections():
    """Set of section numbers DESIGN.md actually defines ('1', '1.2', ...)."""
    text = DESIGN.read_text()
    secs = set()
    for m in re.finditer(r"^#{2,3} §(\d+(?:\.\d+)?)\b", text, re.M):
        secs.add(m.group(1))
    return secs


def test_design_exists_with_numbered_sections():
    secs = design_sections()
    # the sections the issues demand: controller stack, memory model
    # (eq. 12/14), bucketized static shapes, PD fusion, paged KV, prefix
    # sharing, and the two-tier swap space
    assert {"1", "2", "3", "6", "9", "10", "11"} <= secs, secs


def test_source_design_references_resolve():
    secs = design_sections()
    missing = []
    for py in list((ROOT / "src").rglob("*.py")) \
            + list((ROOT / "tests").glob("*.py")) \
            + list((ROOT / "benchmarks").glob("*.py")):
        for m in re.finditer(r"DESIGN §(\d+(?:\.\d+)?)", py.read_text()):
            if m.group(1) not in secs:
                missing.append((str(py.relative_to(ROOT)), m.group(1)))
    assert not missing, f"dangling DESIGN § references: {missing}"


def _md_links(path: Path):
    text = path.read_text()
    # strip fenced code blocks: links inside examples aren't navigation
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    return [m.group(1) for m in re.finditer(r"\]\(([^)#]+)(?:#[^)]*)?\)", text)]


def test_markdown_links_resolve():
    broken = []
    for md in (README, DESIGN):
        for target in _md_links(md):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            if not (md.parent / target).exists():
                broken.append((md.name, target))
    assert not broken, f"broken markdown links: {broken}"


def test_readme_referenced_paths_exist():
    text = README.read_text()
    missing = []
    for m in re.finditer(r"`([\w\-/\.]+\.(?:py|md|txt))`", text):
        if not (ROOT / m.group(1)).exists():
            missing.append(m.group(1))
    # quickstart commands name real files too
    for m in re.finditer(r"python ([\w\-/\.]+\.py)", text):
        if not (ROOT / m.group(1)).exists():
            missing.append(m.group(1))
    assert not missing, f"README references missing files: {missing}"


def test_design_referenced_paths_exist():
    text = DESIGN.read_text()
    missing = []
    for m in re.finditer(r"`([\w\-/\.]+\.(?:py|md|txt))`", text):
        if not (ROOT / m.group(1)).exists():
            missing.append(m.group(1))
    assert not missing, f"DESIGN references missing files: {missing}"
