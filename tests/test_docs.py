"""Docs stay honest: README/docs links resolve, DESIGN section numbers
match every `DESIGN §N` reference in source docstrings, every serve CLI
flag has a README table row, every benchmark runner key and BENCH_*.json
artifact is documented in docs/BENCHMARKS.md, and the quickstart entry
points exist. Run standalone or as the CI docs link-check step."""
import re
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DESIGN = ROOT / "docs" / "DESIGN.md"
BENCHMARKS_MD = ROOT / "docs" / "BENCHMARKS.md"
README = ROOT / "README.md"
ALL_DOCS = (README,) + tuple(sorted((ROOT / "docs").glob("*.md")))


def design_sections():
    """Set of section numbers DESIGN.md actually defines ('1', '1.2', ...)."""
    text = DESIGN.read_text()
    secs = set()
    for m in re.finditer(r"^#{2,3} §(\d+(?:\.\d+)?)\b", text, re.M):
        secs.add(m.group(1))
    return secs


def test_design_exists_with_numbered_sections():
    secs = design_sections()
    # the sections the issues demand: controller stack, memory model
    # (eq. 12/14), bucketized static shapes, PD fusion, paged KV, prefix
    # sharing, the two-tier swap space, mesh-sharded serving, the async
    # pipeline, and trace replay + goodput
    assert {"1", "2", "3", "6", "9", "10", "11", "12", "13", "14",
            "15"} <= secs, secs


def test_source_design_references_resolve():
    secs = design_sections()
    missing = []
    for py in list((ROOT / "src").rglob("*.py")) \
            + list((ROOT / "tests").glob("*.py")) \
            + list((ROOT / "benchmarks").glob("*.py")):
        for m in re.finditer(r"DESIGN §(\d+(?:\.\d+)?)", py.read_text()):
            if m.group(1) not in secs:
                missing.append((str(py.relative_to(ROOT)), m.group(1)))
    assert not missing, f"dangling DESIGN § references: {missing}"


def _md_links(path: Path):
    text = path.read_text()
    # strip fenced code blocks: links inside examples aren't navigation
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    return [m.group(1) for m in re.finditer(r"\]\(([^)#]+)(?:#[^)]*)?\)", text)]


def test_markdown_links_resolve():
    """Link-check over README and every docs/*.md (the CI docs job)."""
    broken = []
    for md in ALL_DOCS:
        for target in _md_links(md):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            if not (md.parent / target).exists():
                broken.append((md.name, target))
    assert not broken, f"broken markdown links: {broken}"


def test_readme_referenced_paths_exist():
    text = README.read_text()
    missing = []
    for m in re.finditer(r"`([\w\-/\.]+\.(?:py|md|txt))`", text):
        if not (ROOT / m.group(1)).exists():
            missing.append(m.group(1))
    # quickstart commands name real files too
    for m in re.finditer(r"python ([\w\-/\.]+\.py)", text):
        if not (ROOT / m.group(1)).exists():
            missing.append(m.group(1))
    assert not missing, f"README references missing files: {missing}"


def test_docs_referenced_paths_exist():
    missing = []
    for md in ALL_DOCS:
        for m in re.finditer(r"`([\w\-/\.]+\.(?:py|md|txt))`",
                             md.read_text()):
            if not (ROOT / m.group(1)).exists():
                missing.append((md.name, m.group(1)))
    assert not missing, f"docs reference missing files: {missing}"


# ---------------------------------------------------------------------------
# flag / runner-key / artifact sync (the next undocumented one fails CI)


def serve_flags():
    """Every --flag registered by launch/serve.py's argparse."""
    text = (ROOT / "src" / "repro" / "launch" / "serve.py").read_text()
    return sorted(set(re.findall(r"add_argument\(\s*\"(--[\w-]+)\"", text)))


def test_every_serve_flag_documented_in_readme():
    """The README's serving-CLI table must carry a row for every flag
    `launch/serve.py` registers — catches the next undocumented flag."""
    text = README.read_text()
    rows = set(re.findall(r"^\|\s*`(--[\w-]+)`", text, re.M))
    flags = serve_flags()
    assert flags, "no serve flags parsed — did serve.py move?"
    missing = [f for f in flags if f not in rows]
    assert not missing, f"serve flags missing from the README table: {missing}"


def runner_keys():
    """The BENCHES tuple in benchmarks/run.py."""
    text = (ROOT / "benchmarks" / "run.py").read_text()
    m = re.search(r"BENCHES\s*=\s*\(([^)]*)\)", text)
    assert m, "BENCHES tuple not found in benchmarks/run.py"
    return sorted(re.findall(r"\"(\w+)\"", m.group(1)))


def test_every_runner_key_documented():
    """docs/BENCHMARKS.md must document every benchmarks/run.py key."""
    text = BENCHMARKS_MD.read_text()
    keys = runner_keys()
    assert keys, "no runner keys parsed"
    missing = [k for k in keys if f"`{k}`" not in text]
    assert not missing, f"runner keys missing from BENCHMARKS.md: {missing}"


def test_every_bench_artifact_documented():
    """Every BENCH_*.json a benchmark writes must have a schema section
    in docs/BENCHMARKS.md."""
    artifacts = set()
    for py in (ROOT / "benchmarks").glob("*.py"):
        artifacts.update(re.findall(r"(BENCH_\w+\.json)", py.read_text()))
    assert artifacts, "no BENCH artifacts found under benchmarks/"
    text = BENCHMARKS_MD.read_text()
    missing = [a for a in sorted(artifacts) if a not in text]
    assert not missing, f"artifacts missing from BENCHMARKS.md: {missing}"
