"""Per-architecture smoke tests: reduced variant, one forward + one train
step on CPU; asserts output shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import ArchFamily, TrainConfig
from repro.config.registry import get_config, list_archs
from repro.models.model import build_model
from repro.training.optimizer import adamw_init, adamw_update
from repro.training.train_loop import make_train_step

ARCHS = list_archs()


def make_batch(cfg, B=2, T=32, seed=0):
    rng = np.random.RandomState(seed)
    batch = {"tokens": jnp.asarray(
        rng.randint(0, cfg.vocab_size, size=(B, T)), jnp.int32)}
    if cfg.family == ArchFamily.ENCDEC:
        batch["enc_frames"] = jnp.asarray(
            rng.randn(B, 16, cfg.d_model), jnp.float32)
    if cfg.family == ArchFamily.VLM:
        batch["images"] = jnp.asarray(
            rng.randn(B, 16, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, "reduced")
    m = build_model(cfg, dtype=jnp.float32)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    logits, aux = m.forward_train(params, batch, remat=False)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = get_config(arch, "reduced")
    m = build_model(cfg, dtype=jnp.float32)
    params = m.init(jax.random.PRNGKey(0))
    tcfg = TrainConfig(global_batch=2, seq_len=32, steps=2, lr=1e-3)
    step = jax.jit(make_train_step(m, tcfg))
    opt = adamw_init(params)
    batch = make_batch(cfg)
    params2, opt2, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_shapes(arch):
    cfg = get_config(arch, "reduced")
    m = build_model(cfg, dtype=jnp.float32)
    params = m.init(jax.random.PRNGKey(0))
    B = 2
    cache = m.init_cache(B, 64, enc_len=16)
    logits, cache2 = m.decode_step(
        params, jnp.zeros((B,), jnp.int32), jnp.zeros((B,), jnp.int32), cache)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)
