"""End-to-end behaviour of the paper's system: the full controller loop
(telemetry -> Alg 1/2 -> admission -> step) on both the simulator and the
real engine, validating the paper's headline claims qualitatively."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ServeConfig
from repro.config.registry import get_config
from repro.models.model import build_model
from repro.serving.cost_model import CostModel, PROFILES
from repro.serving.engine import Engine
from repro.serving.sim import LengthDist, ServingSimulator


def test_paper_claim_throughput_gain_simulated():
    """Table-I-style: dynamic batching beats a fixed vLLM-style preset on an
    infinite backlog (paper: +8..28%; exact gain depends on preset)."""
    cfg = get_config("granite-3-8b")
    cost = CostModel(cfg, PROFILES["a100x8"])
    lengths = LengthDist(mean_in=128, mean_out=128, fixed=True)

    def run(policy, b_max):
        sim = ServingSimulator(
            cfg, ServeConfig(policy=policy, b_max=b_max, max_new_tokens=512),
            cost, lengths, seed=0)
        sim.add_requests(600)
        return sim.run()

    static = run("static", 256)
    dynamic = run("memory", 4096)
    gain = dynamic.throughput_tok_s / static.throughput_tok_s - 1
    assert gain > 0.05
    assert static.finished == dynamic.finished == 600


def test_paper_claim_sla_capacity():
    """Table-II-style: under a TBT SLA, dynamic batching sustains a higher
    arrival rate (capacity) than static batching."""
    cfg = get_config("granite-3-8b")
    cost = CostModel(cfg, PROFILES["paper-fig3"], c0_ms=28.0, c1_ms=0.225)
    lengths = LengthDist(mean_in=256, mean_out=64, fixed=True)
    sla = 60.0

    def attainment(policy, qps, b_max):
        sim = ServingSimulator(
            cfg, ServeConfig(policy=policy, b_max=b_max, d_sla_ms=sla,
                             max_new_tokens=128),
            cost, lengths, seed=0)
        sim.add_requests(300, arrival_rate=qps)
        res = sim.run()
        return res.sla_attainment

    def capacity(policy, b_max):
        cap = 0.0
        for qps in (1, 2, 4, 8, 16, 32):
            if attainment(policy, qps, b_max) >= 0.9:
                cap = qps
        return cap

    cap_static = capacity("static", 512)
    cap_dyn = capacity("combined", 512)
    assert cap_dyn >= cap_static


def test_real_engine_full_loop_with_combined_policy():
    cfg = get_config("granite-3-8b", "reduced")
    m = build_model(cfg, dtype=jnp.float32)
    params = m.init(jax.random.PRNGKey(0))
    serve = ServeConfig(policy="combined", b_max=8, d_sla_ms=500.0,
                        eps_d_ms=100.0, max_new_tokens=6,
                        kv_pool_tokens=1024)
    eng = Engine(m, params, serve, max_context=64, buckets=(1, 2, 4, 8),
                 prefill_chunk=8)
    rng = np.random.RandomState(0)
    handles = [eng.submit(list(map(int, rng.randint(0, cfg.vocab_size,
                                                    size=6))))
               for _ in range(6)]
    eng.run()
    assert eng.total_finished == 6
    assert all(len(h.output_tokens) == 6 for h in handles)
