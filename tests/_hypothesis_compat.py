"""Property-test shim: real `hypothesis` when installed, else a fallback.

Tier-1 must collect and run on a bare image (ROADMAP "Tier-1 verify"), but
`hypothesis` is a dev extra that may be absent. When it is, this module
provides a miniature drop-in for the subset of the API the suite uses
(`given` / `settings` / `strategies.{floats,integers,booleans,sampled_from,
tuples,lists}`) backed by deterministic pseudo-random sampling (seeded per
test, so failures reproduce). It does no shrinking and far less adversarial
generation than the real library — install `requirements-dev.txt` to get
full coverage; CI always runs with the real hypothesis.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised implicitly by which branch collects
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import random

    _DEFAULT_EXAMPLES = 30

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng: random.Random):
            return self._draw(rng)

    class _strategies:
        """Namespace mirroring `hypothesis.strategies` (the used subset)."""

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            lo, hi = float(min_value), float(max_value)

            def draw(rng):
                # mix uniform draws with the endpoints: boundary values are
                # where the real library finds most of its bugs
                r = rng.random()
                if r < 0.05:
                    return lo
                if r < 0.10:
                    return hi
                return rng.uniform(lo, hi)
            return _Strategy(draw)

        @staticmethod
        def integers(min_value=0, max_value=1 << 30, **_kw):
            lo, hi = int(min_value), int(max_value)

            def draw(rng):
                r = rng.random()
                if r < 0.05:
                    return lo
                if r < 0.10:
                    return hi
                return rng.randint(lo, hi)
            return _Strategy(draw)

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: rng.choice(seq))

        @staticmethod
        def tuples(*strats):
            return _Strategy(lambda rng: tuple(s.draw(rng) for s in strats))

        @staticmethod
        def lists(elements, min_size=0, max_size=10, **_kw):
            lo, hi = int(min_size), int(max_size)

            def draw(rng):
                return [elements.draw(rng) for _ in range(rng.randint(lo, hi))]
            return _Strategy(draw)

    strategies = _strategies()

    def settings(max_examples: int = _DEFAULT_EXAMPLES, **_kw):
        """Accepts (and mostly ignores) the real kwargs; keeps max_examples."""
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            def wrapper():
                n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
                for _ in range(n):
                    example = tuple(s.draw(rng) for s in strats)
                    try:
                        fn(*example)
                    except Exception:
                        print(f"Falsifying example ({fn.__qualname__}): "
                              f"{example!r}")
                        raise
            # zero-arg wrapper: pytest must not mistake the strategy
            # parameters for fixtures, so do NOT functools.wraps here
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__dict__.update(fn.__dict__)
            return wrapper
        return deco
