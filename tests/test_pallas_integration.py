"""Pallas backend integration: the decode path through the flash-decode
kernel (interpret mode on CPU) must match the pure-jnp path bitwise-closely."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["REPRO_USE_PALLAS"] = os.environ["WANT_PALLAS"]
    import jax, jax.numpy as jnp, numpy as np
    from repro.config.registry import get_config
    from repro.models.model import build_model

    cfg = get_config("granite-3-8b", "reduced")
    m = build_model(cfg, dtype=jnp.float32)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 12)), jnp.int32)
    pos = jnp.broadcast_to(jnp.arange(12, dtype=jnp.int32)[None], (2, 12))
    cache = m.init_cache(2, 32)
    lg, cache = m.prefill(params, toks, pos, cache, None)
    outs = [int(jnp.argmax(lg[0, -1]))]
    vals = []
    for t in range(12, 18):
        lg, cache = m.decode_step(params, jnp.full((2,), outs[-1], jnp.int32),
                                  jnp.full((2,), t, jnp.int32), cache)
        outs.append(int(jnp.argmax(lg[0])))
        vals.append(np.asarray(lg))
    np.save(os.environ["OUT_NPY"], np.stack(vals))
""")


def run_variant(want: str, out: str):
    env = dict(os.environ, PYTHONPATH=SRC, WANT_PALLAS=want, OUT_NPY=out)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stderr[-2000:]


def test_pallas_decode_matches_jnp(tmp_path):
    import numpy as np
    a, b = str(tmp_path / "a.npy"), str(tmp_path / "b.npy")
    run_variant("0", a)
    run_variant("1", b)
    np.testing.assert_allclose(np.load(a), np.load(b), rtol=2e-4, atol=2e-4)
