"""The CLT chance constraint (eqs. 8-14) against Monte-Carlo ground truth."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.config.registry import get_config
from repro.core.memory_model import MemoryModel, kv_shard_factor

CFG = get_config("granite-3-8b")


def make(budget_gb=32.0, eps=0.05):
    return MemoryModel(CFG, int(budget_gb * 2**30), eps_m=eps)


def test_eta_from_budget():
    m = make(32)
    expect = int(32 * 2**30) // CFG.kv_bytes_per_token()
    assert 0 < m.eta <= expect
    assert m.eta % m.block_size == 0


def test_overflow_prob_monte_carlo():
    """P(S > eta) from eq. (10) must match simulation within CLT error."""
    m = make(4)
    mu_l, var_l = 256.0, 80.0 ** 2
    b = m.b_mem_closed_form(mu_l, var_l)
    rng = np.random.RandomState(0)
    # lognormal lengths with matching moments
    sigma2 = np.log(1 + var_l / mu_l**2)
    mu = np.log(mu_l) - sigma2 / 2
    tot = rng.lognormal(mu, np.sqrt(sigma2), size=(20000, b)).sum(axis=1)
    p_emp = (tot > m.eta).mean()
    p_model = m.overflow_prob(b, mu_l, var_l)
    assert abs(p_emp - p_model) < 0.03
    assert p_model <= m.eps_m + 1e-9


@given(st.floats(32, 2048), st.floats(0, 500**2), st.floats(0.01, 0.2))
@settings(max_examples=100, deadline=None)
def test_closed_form_satisfies_constraint(mu_l, var_l, eps):
    m = MemoryModel(CFG, 16 * 2**30, eps_m=eps)
    b = m.b_mem_closed_form(mu_l, var_l)
    assert b >= 1
    if b > 1:
        assert m.overflow_prob(b, mu_l, var_l) <= eps + 1e-6
    # b+1 must violate (or be capacity-trivial)
    if m.overflow_prob(b + 1, mu_l, var_l) <= eps - 1e-6:
        # closed form may round down conservatively by at most ~1
        assert m.overflow_prob(b + 2, mu_l, var_l) > eps - 1e-6


def test_linear_rule_tracks_closed_form():
    """Eq. (14) with the paper's L0 = eta - (theta*sigma_S + mu_S) evaluated
    at b* overshoots (12) by exactly theta*sigma_S(b*)/mu_l — the paper
    treats memory as a soft constraint and absorbs this with preemption
    (paper §II-A); we assert the analytical relation and that the overshoot
    stays within 5%."""
    import math
    m = make(32)
    mu_l, var_l = 256.0, 100.0 ** 2
    b_star = m.b_mem_closed_form(mu_l, var_l)
    L0 = m.safety_buffer_L0(b_star, mu_l, var_l)
    b_lin = m.b_mem_linear(L0, mu_l)
    overshoot = m.theta * math.sqrt(b_star * var_l) / mu_l
    assert abs(b_lin - (b_star + overshoot)) <= 2
    assert b_lin - b_star <= max(2, 0.05 * b_star)


def test_l0_is_positive_buffer():
    m = make(32)
    b = m.b_mem_closed_form(256.0, 100.0 ** 2)
    L0 = m.safety_buffer_L0(b, 256.0, 100.0 ** 2)
    assert L0 >= 0.0           # safety buffer protects the tail
    assert L0 <= m.eta


def test_ssm_degenerates_to_request_cap():
    cfg = get_config("mamba2-2.7b")
    m = MemoryModel(cfg, 8 * 2**30)
    assert m.bytes_per_token == 0
    assert m.eta == 0
    cap = m.max_requests_state_only()
    assert cap >= 1
    assert m.overflow_prob(cap, 1000.0, 0.0) == 0.0
    assert m.overflow_prob(cap + 1, 1000.0, 0.0) == 1.0
    assert m.b_mem_closed_form(1000.0, 0.0) == cap


def test_window_truncates_moments():
    cfg = get_config("recurrentgemma-9b")
    m = MemoryModel(cfg, 8 * 2**30)
    mu, var = m.effective_moments(4096, 1000.0, 4096, 1000.0)
    assert mu == cfg.rglru.window_size          # capped at the window
    mu2, var2 = m.effective_moments(100, 10.0, 100, 10.0)
    assert mu2 == 200                            # below window: untouched


def test_fixed_bytes_per_request():
    enc = get_config("seamless-m4t-medium")
    m = MemoryModel(enc, 8 * 2**30)
    fixed = m.fixed_bytes_per_request(enc_len=1024)
    # 12 decoder layers of cross KV at 1024 positions
    assert fixed == 2 * 12 * 1024 * 16 * 64 * 2


# ---------------------------------------------------------------------------
# chip-aware pool under mesh-sharded serving (DESIGN §12)


def test_eta_scales_with_model_shards():
    """Per-chip HBM budget × model-axis shards worth of tokens fit when
    each token's KV is split over the model axis."""
    one = make(8)
    for m in (2, 4):
        sharded = MemoryModel(CFG, int(8 * 2**30), eps_m=0.05, model_shards=m)
        # scaling happens before block rounding: within one block of m×
        assert m * one.eta <= sharded.eta <= m * one.eta + m * one.block_size
        assert sharded.eta % sharded.block_size == 0
        # the §7 watermark (num_blocks // 100) sees the sharded pool
        assert sharded.num_blocks // 100 >= m * (one.num_blocks // 100)


def test_eta_tokens_override_is_per_chip():
    one = MemoryModel(CFG, 0, eta_tokens=256)
    two = MemoryModel(CFG, 0, eta_tokens=256, model_shards=2)
    assert one.eta == 256 and two.eta == 512


def test_kv_shard_factor_gating():
    # granite-3-8b full: 8 kv heads, head_dim 128
    assert kv_shard_factor(CFG, 1) == 1
    assert kv_shard_factor(CFG, 2) == 2           # kv heads divide
    assert kv_shard_factor(CFG, 8) == 8
    assert kv_shard_factor(CFG, 16) == 16         # head_dim fallback (8 % 16)
    assert kv_shard_factor(CFG, 3) == 1           # neither divides: no scale
    # attention-free SSM: no token pool to shard — capacity must not scale
    ssm = get_config("mamba2-2.7b")
    assert kv_shard_factor(ssm, 4) == 1
    m = MemoryModel(ssm, 8 * 2**30, model_shards=kv_shard_factor(ssm, 4))
    assert m.eta == 0


def test_b_mem_sees_sharded_pool():
    """Alg 1's capacity rule admits ~m× the requests at fixed per-chip
    HBM when the pool shards m ways."""
    one = make(8)
    two = MemoryModel(CFG, int(8 * 2**30), eps_m=0.05, model_shards=2)
    b1 = one.b_mem_closed_form(512.0, 128.0 ** 2)
    b2 = two.b_mem_closed_form(512.0, 128.0 ** 2)
    assert b2 > b1
    assert abs(b2 - 2 * b1) <= max(4, 0.02 * b2)
