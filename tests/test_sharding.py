"""Distribution tests: sharding specs resolve + a small-mesh compile smoke.

A subprocess gets 8 fake CPU devices (the 512-device production dry-run runs
via launch/dryrun.py; here we prove the machinery on a (4, 2) mesh quickly).
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parent.parent / "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from repro.config.registry import get_config
    from repro.config.base import TrainConfig, InputShape
    from repro.distributed.sharding import (param_shardings, batch_shardings,
                                            decode_input_shardings)
    from repro.models.model import Model, input_specs
    from repro.training.optimizer import adamw_init
    from repro.training.train_loop import make_train_step

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    out = {}
    for arch in ["granite-3-8b", "qwen2-moe-a2.7b", "mamba2-2.7b",
                 "recurrentgemma-9b"]:
        cfg = get_config(arch, "reduced")
        model = Model(cfg, dtype=jnp.float32)
        pshapes = model.init_shapes()
        pshard = param_shardings(pshapes, cfg, mesh)

        # train step lowers + compiles on the mesh
        tcfg = TrainConfig(global_batch=4, seq_len=16)
        shape = InputShape("t", 16, 4, "train")
        specs = input_specs(cfg, shape, dtype=jnp.float32)
        oshapes = jax.eval_shape(adamw_init, pshapes)
        bshard = batch_shardings(specs, cfg, mesh)
        fn = make_train_step(model, tcfg)
        with mesh:
            compiled = jax.jit(fn, in_shardings=(pshard, None, bshard)) \\
                .lower(pshapes, oshapes, specs).compile()
        txt = compiled.as_text()
        out[arch] = {
            "train_ok": True,
            "has_collectives": ("all-reduce" in txt or "all-gather" in txt),
        }

        # decode step
        dshape = InputShape("d", 64, 4, "decode")
        dspecs = input_specs(cfg, dshape, dtype=jnp.float32)
        tok_sh = decode_input_shardings(cfg, mesh, 4)
        def serve_step(params, tokens, seq_lens, cache):
            lg, cache = model.decode_step(params, tokens, seq_lens, cache)
            return jnp.argmax(lg, -1), cache
        with mesh:
            jax.jit(serve_step, in_shardings=(pshard, tok_sh, tok_sh, None)) \\
                .lower(pshapes, dspecs["tokens"], dspecs["seq_lens"],
                       dspecs["cache"]).compile()
        out[arch]["decode_ok"] = True
    print("RESULT" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def mesh_results():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


@pytest.mark.parametrize("arch", ["granite-3-8b", "qwen2-moe-a2.7b",
                                  "mamba2-2.7b", "recurrentgemma-9b"])
def test_train_and_decode_compile_on_mesh(mesh_results, arch):
    r = mesh_results[arch]
    assert r["train_ok"] and r["decode_ok"]
    assert r["has_collectives"]  # sharded training must communicate


def test_spec_validation_drops_indivisible_axes():
    """36 heads on a 16-way model axis must not shard (starcoder2)."""
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import _validate

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    m = FakeMesh()
    spec = _validate(P(None, "model"), (10, 36), m)
    assert spec == P(None, None)
    spec2 = _validate(P(None, "model"), (10, 64), m)
    assert spec2 == P(None, "model")
