"""End-to-end engine tests: batched == unbatched, preemption, policies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import ServeConfig
from repro.config.registry import get_config
from repro.models.model import build_model
from repro.serving.engine import Engine


def setup_model(arch):
    cfg = get_config(arch, "reduced")
    m = build_model(cfg, dtype=jnp.float32)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def ref_generate(m, params, prompt, n_new, extras=None, max_ctx=64):
    cache = m.init_cache(1, max_ctx, enc_len=16)
    T = len(prompt)
    lg, cache = m.prefill(params, jnp.array([prompt], jnp.int32),
                          jnp.arange(T, dtype=jnp.int32)[None], cache, extras)
    out = [int(jnp.argmax(lg[0, T - 1]))]
    for i in range(n_new - 1):
        lg, cache = m.decode_step(params, jnp.array([out[-1]], jnp.int32),
                                  jnp.array([T + i], jnp.int32), cache)
        out.append(int(jnp.argmax(lg[0])))
    return out


@pytest.mark.parametrize("arch", ["granite-3-8b", "mamba2-2.7b"])
@pytest.mark.parametrize("policy", ["static", "memory"])
def test_batched_equals_unbatched(arch, policy):
    cfg, m, params = setup_model(arch)
    rng = np.random.RandomState(0)
    prompts = [list(map(int, rng.randint(0, cfg.vocab_size,
                                         size=rng.randint(4, 20))))
               for _ in range(4)]
    refs = [ref_generate(m, params, p, 6) for p in prompts]
    serve = ServeConfig(policy=policy, b_max=4, max_new_tokens=6,
                        kv_pool_tokens=2048)
    eng = Engine(m, params, serve, max_context=64, buckets=(1, 2, 4),
                 prefill_chunk=8)
    handles = [eng.submit(p, max_new_tokens=6) for p in prompts]
    eng.run()
    for h, want in zip(handles, refs):
        assert h.output_tokens == want


def test_preemption_recovers_and_completes():
    cfg, m, params = setup_model("granite-3-8b")
    rng = np.random.RandomState(1)
    prompts = [list(map(int, rng.randint(0, cfg.vocab_size, size=10)))
               for _ in range(6)]
    # pool of 192 tokens (12 blocks): 6 requests growing to 50 tokens each
    # need 24 blocks — static admission over-commits and must preempt
    serve = ServeConfig(policy="static", b_max=8, max_new_tokens=40,
                        kv_pool_tokens=192, block_size=16)
    eng = Engine(m, params, serve, max_context=64, buckets=(1, 2, 4, 8),
                 prefill_chunk=8)
    handles = [eng.submit(p, max_new_tokens=40) for p in prompts]
    eng.run(max_steps=5000)
    assert eng.total_finished == 6
    assert all(len(h.output_tokens) > 0 for h in handles)
    # static over-admission against a tiny pool MUST have preempted
    assert eng.preemptions > 0


def test_memory_policy_avoids_preemption_vs_static():
    """The paper's core claim in miniature: memory-aware admission avoids
    the preemption storms static batching hits on a tight pool."""
    cfg, m, params = setup_model("granite-3-8b")

    def run(policy):
        rng = np.random.RandomState(2)
        serve = ServeConfig(policy=policy, b_max=8, max_new_tokens=24,
                            kv_pool_tokens=384, block_size=16)
        eng = Engine(m, params, serve, max_context=64,
                     buckets=(1, 2, 4, 8), prefill_chunk=8)
        for _ in range(8):
            eng.submit(list(map(int, rng.randint(0, cfg.vocab_size, size=8))),
                       max_new_tokens=24)
        eng.run(max_steps=5000)
        return eng

    static = run("static")
    dynamic = run("memory")
    assert static.total_finished == dynamic.total_finished == 8
    assert dynamic.preemptions <= static.preemptions


def test_engine_telemetry_feeds_policy():
    cfg, m, params = setup_model("granite-3-8b")
    serve = ServeConfig(policy="memory", b_max=8, max_new_tokens=4,
                        kv_pool_tokens=2048)
    eng = Engine(m, params, serve, max_context=64, buckets=(1, 2, 4, 8),
                 prefill_chunk=8)
    rng = np.random.RandomState(3)
    for _ in range(3):
        eng.submit(list(map(int, rng.randint(0, cfg.vocab_size, size=6))))
    eng.run()
    s = eng.summary()
    assert s["finished"] == 3
    assert s["decode_steps"] > 0
    assert s["tbt_ms_mean"] > 0
    assert len(eng.tel.tbt) > 0


def test_multimodal_requests_roundtrip():
    cfg, m, params = setup_model("llama-3.2-vision-90b")
    rng = np.random.RandomState(4)
    extras = {"images": jnp.asarray(rng.randn(1, 16, cfg.d_model), jnp.float32)}
    prompt = list(map(int, rng.randint(0, cfg.vocab_size, size=6)))
    want = ref_generate(m, params, prompt, 5, extras=extras)
    serve = ServeConfig(policy="memory", b_max=2, max_new_tokens=5,
                        kv_pool_tokens=1024)
    eng = Engine(m, params, serve, max_context=64, buckets=(1, 2),
                 prefill_chunk=8, enc_len=16)
    h = eng.submit(prompt, max_new_tokens=5, extras=extras)
    eng.run()
    assert h.output_tokens == want
