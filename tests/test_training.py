"""Training substrate: learning, schedule, checkpoint round-trip."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import TrainConfig
from repro.config.registry import get_config
from repro.models.model import build_model
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.optimizer import adamw_init, adamw_update, lr_schedule
from repro.training.train_loop import train


def test_loss_decreases_dense(tmp_path):
    cfg = get_config("granite-3-8b", "reduced")
    m = build_model(cfg, dtype=jnp.float32)
    t = TrainConfig(global_batch=8, seq_len=64, steps=50, lr=3e-3,
                    warmup_steps=10, log_every=100)
    res = train(m, t, log=None)
    first = sum(res["losses"][:5]) / 5
    last = sum(res["losses"][-5:]) / 5
    assert last < first - 0.5, (first, last)


def test_lr_schedule_shape():
    t = TrainConfig(steps=100, warmup_steps=10, lr=1e-3)
    lrs = [float(lr_schedule(jnp.asarray(s), t)) for s in range(1, 101)]
    assert lrs[4] < lrs[9]                 # warmup rising
    assert max(lrs) <= 1e-3 + 1e-9
    assert lrs[-1] < lrs[20]               # cosine decaying


def test_grad_clip_bounds_update():
    t = TrainConfig(grad_clip=1.0, lr=1.0, warmup_steps=0, steps=1)
    params = {"w": jnp.zeros((4,))}
    grads = {"w": jnp.full((4,), 100.0)}
    opt = adamw_init(params)
    p2, _, metrics = adamw_update(params, grads, opt, t)
    assert float(metrics["grad_norm"]) > 100.0
    assert bool(jnp.all(jnp.abs(p2["w"]) < 10.0))


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("starcoder2-7b", "reduced")
    m = build_model(cfg, dtype=jnp.float32)
    params = m.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    path = os.path.join(tmp_path, "ckpt.npz")
    save_checkpoint(path, params, opt, step=7)
    p2, o2, step = load_checkpoint(path, params, opt)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_markov_data_deterministic():
    from repro.training.data import MarkovData
    cfg = get_config("granite-3-8b", "reduced")
    t = TrainConfig(global_batch=2, seq_len=16, seed=3)
    a = next(MarkovData(cfg, t).batches())["tokens"]
    b = next(MarkovData(cfg, t).batches())["tokens"]
    np.testing.assert_array_equal(a, b)
